//! The explicitly vectorized kernels: x86_64 AVX2+FMA micro-kernels,
//! runtime feature-detected — the third `Kernel` variant.
//!
//! Structure (shared with `blocked`): threads partition **output rows**
//! (`parallel_chunks`), `NC`-wide output-column panels and `KC`-deep
//! reduction slices park running sums in `C` between passes, and a
//! register micro-kernel does the inner work. What changes is the
//! micro-kernel itself:
//!
//! * `nt` / `block_diag` (both operands row-major along `k`): a 4-row ×
//!   2-column tile of 8 ymm accumulators, each vectorized **along `k`**
//!   8 lanes wide with `vfmadd`, horizontally reduced per k-slice and a
//!   scalar ragged tail;
//! * `nn` / `tn` (B is `k`-major, its `n` lane contiguous): a 4-row ×
//!   16-column (2 ymm per row) tile, one `_mm256_set1_ps` broadcast of
//!   A per row per `kk` and `vfmadd` into per-element lane chains.
//!
//! **Exactness tier.** This kernel deliberately leaves the subsystem's
//! bit-identity contract (`mod.rs`): `vfmadd` fuses multiply and add
//! into one rounding, and the `nt`-family k-vectorization splits the
//! reduction into 8 interleaved partial sums reduced at slice
//! boundaries. Results are therefore only **bounded-ulp** close to the
//! naive oracle — `rust/tests/kernels.rs` enforces the bound (second
//! test tier) while naive/blocked stay bit-exact. Two invariants ARE
//! preserved: results never depend on the thread count (threads
//! partition output rows and `parallel_chunks` keeps chunk boundaries
//! `MR`-aligned at every worker count, so each row keeps the same
//! tile-vs-edge path and its per-element math depends only on the k
//! slicing), and exact integer arithmetic stays exact (fusing or
//! reassociating error-free operations is error-free — the golden
//! checkpoint fixture relies on this).
//!
//! **Availability.** `available()` runtime-detects AVX2+FMA via
//! `is_x86_feature_detected!` — no compile-time feature flags are
//! needed to build. On CPUs (or architectures) without the features,
//! every entry point silently delegates to `blocked`, so a
//! `KernelConfig` carrying `Kernel::Simd` is safe everywhere and env
//! selection can warn-and-fall-back instead of panicking.

use super::{blocked, BlockDiag, Tile};

/// Output columns per NT-family micro-tile (`k` is the vector axis).
pub const SIMD_NT_COLS: usize = 2;
/// Output columns per NN/TN micro-tile (two 8-lane ymm per row).
pub const SIMD_NR: usize = 16;

/// Does this host support the AVX2+FMA micro-kernels? Checked at
/// runtime; `false` on non-x86_64 builds.
pub(super) fn available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// `C[m,n] = A[m,k] · B[n,k]ᵀ` (falls back to `blocked` off-AVX2).
#[allow(clippy::too_many_arguments)]
pub(super) fn nt(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    tile: Tile,
    threads: usize,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if available() {
            x86::nt(a, b, c, m, k, n, tile, threads);
            return;
        }
    }
    blocked::nt(a, b, c, m, k, n, tile, threads)
}

/// `C[m,n] = A[m,k] · B[k,n]` (falls back to `blocked` off-AVX2).
#[allow(clippy::too_many_arguments)]
pub(super) fn nn(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    tile: Tile,
    threads: usize,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if available() {
            x86::nn(a, b, c, m, k, n, tile, threads);
            return;
        }
    }
    blocked::nn(a, b, c, m, k, n, tile, threads)
}

/// `C[m,n] = A[k,m]ᵀ · B[k,n]` (falls back to `blocked` off-AVX2).
#[allow(clippy::too_many_arguments)]
pub(super) fn tn(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    tile: Tile,
    threads: usize,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if available() {
            x86::tn(a, b, c, m, k, n, tile, threads);
            return;
        }
    }
    blocked::tn(a, b, c, m, k, n, tile, threads)
}

/// Packed block-diagonal product (falls back to `blocked` off-AVX2).
#[allow(clippy::too_many_arguments)]
pub(super) fn block_diag(
    input: &[f32],
    w: &[f32],
    bias: &[f32],
    out: &mut [f32],
    rows: usize,
    w_in: usize,
    w_out: usize,
    bd: &BlockDiag<'_>,
    threads: usize,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if available() {
            x86::block_diag(input, w, bias, out, rows, w_in, w_out, bd, threads);
            return;
        }
    }
    blocked::block_diag(input, w, bias, out, rows, w_in, w_out, bd, threads)
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::super::{blocked, BlockDiag, Tile, MR};
    use super::{SIMD_NR, SIMD_NT_COLS};
    use crate::util::threadpool::{parallel_chunks, SendPtr};
    use core::arch::x86_64::*;

    /// f32 lanes per ymm register.
    const LANES: usize = 8;

    /// Horizontal sum of one ymm register (the per-element reduction at
    /// k-slice boundaries in the NT-family micro-kernels).
    ///
    /// SAFETY: callers must run on an AVX2+FMA host (the drivers check
    /// `available()` before entering this module's kernels).
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    // under deny(unsafe_op_in_unsafe_fn) these register-only intrinsics
    // need the explicit unsafe block on older toolchains; newer ones
    // (1.87+) make them safe-in-context here, so the block is "unused"
    #[allow(unused_unsafe)]
    unsafe fn hsum256(v: __m256) -> f32 {
        // SAFETY: register-only lane shuffles/adds — no memory access;
        // the target-feature obligation is discharged by the caller
        unsafe {
            let lo = _mm256_castps256_ps128(v);
            let hi = _mm256_extractf128_ps(v, 1);
            let s = _mm_add_ps(lo, hi);
            let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
            let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 0b01));
            _mm_cvtss_f32(s)
        }
    }

    /// NT-family micro-tile: 4 rows × 2 columns, `k` vectorized 8-wide
    /// with FMA. Computes the k-slice `[k0, k1)` partial dot of row
    /// `a0 + ii·astr` against row `b0 + jj·bstr` and **adds** it onto
    /// the running totals parked in `crows` (element `(ii, jj)` at
    /// `crow0 + ii·cstr + jj`). Ragged k-tail is scalar.
    ///
    /// SAFETY: caller guarantees `a0 + (MR-1)·astr + k1 <= a.len()`,
    /// `b0 + (SIMD_NT_COLS-1)·bstr + k1 <= b.len()`, and the `crows`
    /// tile in bounds; must only run on AVX2+FMA hosts.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn nt_tile(
        a: &[f32],
        a0: usize,
        astr: usize,
        b: &[f32],
        b0: usize,
        bstr: usize,
        crows: &mut [f32],
        crow0: usize,
        cstr: usize,
        k0: usize,
        k1: usize,
    ) {
        debug_assert!(k1 == k0 || a0 + (MR - 1) * astr + k1 <= a.len());
        debug_assert!(k1 == k0 || b0 + (SIMD_NT_COLS - 1) * bstr + k1 <= b.len());
        debug_assert!(crow0 + (MR - 1) * cstr + SIMD_NT_COLS <= crows.len());
        // SAFETY: the fn's contract (doc comment) puts every loadu inside
        // a/b; the caller verified AVX2+FMA before dispatching here
        unsafe {
            let mut acc = [[_mm256_setzero_ps(); SIMD_NT_COLS]; MR];
            let mut kk = k0;
            while kk + LANES <= k1 {
                let bv0 = _mm256_loadu_ps(b.as_ptr().add(b0 + kk));
                let bv1 = _mm256_loadu_ps(b.as_ptr().add(b0 + bstr + kk));
                for (ii, accrow) in acc.iter_mut().enumerate() {
                    let av = _mm256_loadu_ps(a.as_ptr().add(a0 + ii * astr + kk));
                    accrow[0] = _mm256_fmadd_ps(av, bv0, accrow[0]);
                    accrow[1] = _mm256_fmadd_ps(av, bv1, accrow[1]);
                }
                kk += LANES;
            }
            for (ii, accrow) in acc.iter().enumerate() {
                for (jj, &accv) in accrow.iter().enumerate() {
                    let mut s = hsum256(accv);
                    for kt in kk..k1 {
                        s += a[a0 + ii * astr + kt] * b[b0 + jj * bstr + kt];
                    }
                    crows[crow0 + ii * cstr + jj] += s;
                }
            }
        }
    }

    /// NN micro-tile: 4 rows × 16 columns (2 ymm per row), one
    /// `_mm256_set1_ps` broadcast of `a[(i+ii)·k + kk]` per row per
    /// `kk`, FMA into per-element lane chains. The running C tile is
    /// loaded/stored around the k-slice, so each output element keeps a
    /// single in-order k chain (only the fused rounding differs from
    /// the oracle).
    ///
    /// SAFETY: caller guarantees `(i+MR)·k <= a.len()`,
    /// `kk·n + j + SIMD_NR <= b.len()` for all `kk < k1`, and the
    /// `crows` tile in bounds; AVX2+FMA host only.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn nn_tile(
        a: &[f32],
        b: &[f32],
        crows: &mut [f32],
        crow0: usize,
        cstr: usize,
        i: usize,
        j: usize,
        k: usize,
        n: usize,
        k0: usize,
        k1: usize,
    ) {
        debug_assert!(k1 == k0 || (i + MR) * k <= a.len());
        debug_assert!(k1 == k0 || (k1 - 1) * n + j + SIMD_NR <= b.len());
        debug_assert!(crow0 + (MR - 1) * cstr + SIMD_NR <= crows.len());
        // SAFETY: the fn's contract (doc comment) puts every load/store
        // inside a/b/crows; AVX2+FMA verified by the caller
        unsafe {
            let mut acc = [[_mm256_setzero_ps(); 2]; MR];
            for (ii, accrow) in acc.iter_mut().enumerate() {
                let base = crow0 + ii * cstr;
                accrow[0] = _mm256_loadu_ps(crows.as_ptr().add(base));
                accrow[1] = _mm256_loadu_ps(crows.as_ptr().add(base + LANES));
            }
            for kk in k0..k1 {
                let bv0 = _mm256_loadu_ps(b.as_ptr().add(kk * n + j));
                let bv1 = _mm256_loadu_ps(b.as_ptr().add(kk * n + j + LANES));
                for (ii, accrow) in acc.iter_mut().enumerate() {
                    let av = _mm256_set1_ps(*a.get_unchecked((i + ii) * k + kk));
                    accrow[0] = _mm256_fmadd_ps(av, bv0, accrow[0]);
                    accrow[1] = _mm256_fmadd_ps(av, bv1, accrow[1]);
                }
            }
            for (ii, accrow) in acc.iter().enumerate() {
                let base = crow0 + ii * cstr;
                _mm256_storeu_ps(crows.as_mut_ptr().add(base), accrow[0]);
                _mm256_storeu_ps(crows.as_mut_ptr().add(base + LANES), accrow[1]);
            }
        }
    }

    /// TN micro-tile: as [`nn_tile`] but A is `k`-major — the broadcast
    /// reads `a[kk·m + i + ii]` (a rank-1 update per `kk`).
    ///
    /// SAFETY: caller guarantees `i + MR <= m`, `k1·m <= a.len()`,
    /// `kk·n + j + SIMD_NR <= b.len()` for all `kk < k1`, and the
    /// `crows` tile in bounds; AVX2+FMA host only.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn tn_tile(
        a: &[f32],
        b: &[f32],
        crows: &mut [f32],
        crow0: usize,
        cstr: usize,
        i: usize,
        j: usize,
        m: usize,
        n: usize,
        k0: usize,
        k1: usize,
    ) {
        debug_assert!(k1 == k0 || (k1 - 1) * m + i + MR <= a.len());
        debug_assert!(k1 == k0 || (k1 - 1) * n + j + SIMD_NR <= b.len());
        debug_assert!(crow0 + (MR - 1) * cstr + SIMD_NR <= crows.len());
        // SAFETY: the fn's contract (doc comment) puts every load/store
        // inside a/b/crows; AVX2+FMA verified by the caller
        unsafe {
            let mut acc = [[_mm256_setzero_ps(); 2]; MR];
            for (ii, accrow) in acc.iter_mut().enumerate() {
                let base = crow0 + ii * cstr;
                accrow[0] = _mm256_loadu_ps(crows.as_ptr().add(base));
                accrow[1] = _mm256_loadu_ps(crows.as_ptr().add(base + LANES));
            }
            for kk in k0..k1 {
                let bv0 = _mm256_loadu_ps(b.as_ptr().add(kk * n + j));
                let bv1 = _mm256_loadu_ps(b.as_ptr().add(kk * n + j + LANES));
                for (ii, accrow) in acc.iter_mut().enumerate() {
                    let av = _mm256_set1_ps(*a.get_unchecked(kk * m + i + ii));
                    accrow[0] = _mm256_fmadd_ps(av, bv0, accrow[0]);
                    accrow[1] = _mm256_fmadd_ps(av, bv1, accrow[1]);
                }
            }
            for (ii, accrow) in acc.iter().enumerate() {
                let base = crow0 + ii * cstr;
                _mm256_storeu_ps(crows.as_mut_ptr().add(base), accrow[0]);
                _mm256_storeu_ps(crows.as_mut_ptr().add(base + LANES), accrow[1]);
            }
        }
    }

    /// `C[m,n] = A[m,k] · B[n,k]ᵀ`.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn nt(
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        tile: Tile,
        threads: usize,
    ) {
        let cp = SendPtr(c.as_mut_ptr());
        let nc = tile.nc.max(SIMD_NT_COLS);
        let kc = tile.kc.max(1);
        parallel_chunks(m, threads, MR, move |r0, r1| {
            debug_assert!(r0 % MR == 0, "simd nt chunk start {r0} off the MR={MR} grid");
            // SAFETY: rows [r0, r1) are owned exclusively by this chunk
            let crows =
                unsafe { std::slice::from_raw_parts_mut(cp.ptr().add(r0 * n), (r1 - r0) * n) };
            crows.iter_mut().for_each(|x| *x = 0.0);
            let mut jc = 0;
            while jc < n {
                let jend = (jc + nc).min(n);
                let mut ks = 0;
                while ks < k.max(1) {
                    let kend = (ks + kc).min(k);
                    let mut i = r0;
                    while i + MR <= r1 {
                        let mut j = jc;
                        while j + SIMD_NT_COLS <= jend {
                            // SAFETY: full MR×2 tile, k-slice within k,
                            // AVX2+FMA verified by the caller
                            unsafe {
                                nt_tile(
                                    a,
                                    i * k,
                                    k,
                                    b,
                                    j * k,
                                    k,
                                    crows,
                                    (i - r0) * n + j,
                                    n,
                                    ks,
                                    kend,
                                );
                            }
                            j += SIMD_NT_COLS;
                        }
                        blocked::edge_nt(a, b, crows, r0, i, i + MR, j, jend, ks, kend, k, n);
                        i += MR;
                    }
                    blocked::edge_nt(a, b, crows, r0, i, r1, jc, jend, ks, kend, k, n);
                    ks = kend.max(ks + 1);
                }
                jc = jend;
            }
        });
    }

    /// `C[m,n] = A[m,k] · B[k,n]`.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn nn(
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        tile: Tile,
        threads: usize,
    ) {
        let cp = SendPtr(c.as_mut_ptr());
        let nc = tile.nc.max(SIMD_NR);
        let kc = tile.kc.max(1);
        parallel_chunks(m, threads, MR, move |r0, r1| {
            debug_assert!(r0 % MR == 0, "simd nn chunk start {r0} off the MR={MR} grid");
            // SAFETY: rows [r0, r1) are owned exclusively by this chunk
            let crows =
                unsafe { std::slice::from_raw_parts_mut(cp.ptr().add(r0 * n), (r1 - r0) * n) };
            crows.iter_mut().for_each(|x| *x = 0.0);
            let mut jc = 0;
            while jc < n {
                let jend = (jc + nc).min(n);
                let mut ks = 0;
                while ks < k.max(1) {
                    let kend = (ks + kc).min(k);
                    let mut i = r0;
                    while i + MR <= r1 {
                        let mut j = jc;
                        while j + SIMD_NR <= jend {
                            // SAFETY: full MR×16 tile in bounds; a is
                            // indexed (i+ii)·k + kk with kk < k
                            unsafe {
                                nn_tile(
                                    a,
                                    b,
                                    crows,
                                    (i - r0) * n + j,
                                    n,
                                    i,
                                    j,
                                    k,
                                    n,
                                    ks,
                                    kend,
                                );
                            }
                            j += SIMD_NR;
                        }
                        blocked::edge_nn(a, b, crows, r0, i, i + MR, j, jend, ks, kend, k, n);
                        i += MR;
                    }
                    blocked::edge_nn(a, b, crows, r0, i, r1, jc, jend, ks, kend, k, n);
                    ks = kend.max(ks + 1);
                }
                jc = jend;
            }
        });
    }

    /// `C[m,n] = A[k,m]ᵀ · B[k,n]`.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn tn(
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        tile: Tile,
        threads: usize,
    ) {
        let cp = SendPtr(c.as_mut_ptr());
        let nc = tile.nc.max(SIMD_NR);
        let kc = tile.kc.max(1);
        parallel_chunks(m, threads, MR, move |r0, r1| {
            debug_assert!(r0 % MR == 0, "simd tn chunk start {r0} off the MR={MR} grid");
            // SAFETY: rows [r0, r1) are owned exclusively by this chunk
            let crows =
                unsafe { std::slice::from_raw_parts_mut(cp.ptr().add(r0 * n), (r1 - r0) * n) };
            crows.iter_mut().for_each(|x| *x = 0.0);
            let mut jc = 0;
            while jc < n {
                let jend = (jc + nc).min(n);
                let mut ks = 0;
                while ks < k.max(1) {
                    let kend = (ks + kc).min(k);
                    let mut i = r0;
                    while i + MR <= r1 {
                        let mut j = jc;
                        while j + SIMD_NR <= jend {
                            // SAFETY: i + MR <= m (driver bound), kk < k
                            unsafe {
                                tn_tile(
                                    a,
                                    b,
                                    crows,
                                    (i - r0) * n + j,
                                    n,
                                    i,
                                    j,
                                    m,
                                    n,
                                    ks,
                                    kend,
                                );
                            }
                            j += SIMD_NR;
                        }
                        blocked::edge_tn(a, b, crows, r0, i, i + MR, j, jend, ks, kend, m, n);
                        i += MR;
                    }
                    blocked::edge_tn(a, b, crows, r0, i, r1, jc, jend, ks, kend, m, n);
                    ks = kend.max(ks + 1);
                }
                jc = jend;
            }
        });
    }

    /// NT-shaped micro-tile for one model block of the packed
    /// block-diagonal product: single full pass over `fan_in` (no
    /// k-blocking — blocks are one model's fan-in), bias added once
    /// after the reduction, result **stored** (not accumulated).
    ///
    /// SAFETY: caller guarantees the full MR×2 tile and both packed
    /// weight rows in bounds; AVX2+FMA host only.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn bd_tile(
        input: &[f32],
        in0: usize,
        instr: usize,
        w: &[f32],
        w0: usize,
        wstr: usize,
        bias: &[f32],
        bias0: usize,
        orows: &mut [f32],
        o0: usize,
        ostr: usize,
        fan_in: usize,
    ) {
        debug_assert!(fan_in == 0 || in0 + (MR - 1) * instr + fan_in <= input.len());
        debug_assert!(fan_in == 0 || w0 + (SIMD_NT_COLS - 1) * wstr + fan_in <= w.len());
        debug_assert!(bias0 + SIMD_NT_COLS <= bias.len());
        debug_assert!(o0 + (MR - 1) * ostr + SIMD_NT_COLS <= orows.len());
        // SAFETY: the fn's contract (doc comment) puts every loadu inside
        // input/w; AVX2+FMA verified by the caller
        unsafe {
            let mut acc = [[_mm256_setzero_ps(); SIMD_NT_COLS]; MR];
            let mut kk = 0;
            while kk + LANES <= fan_in {
                let wv0 = _mm256_loadu_ps(w.as_ptr().add(w0 + kk));
                let wv1 = _mm256_loadu_ps(w.as_ptr().add(w0 + wstr + kk));
                for (ii, accrow) in acc.iter_mut().enumerate() {
                    let iv = _mm256_loadu_ps(input.as_ptr().add(in0 + ii * instr + kk));
                    accrow[0] = _mm256_fmadd_ps(iv, wv0, accrow[0]);
                    accrow[1] = _mm256_fmadd_ps(iv, wv1, accrow[1]);
                }
                kk += LANES;
            }
            for (ii, accrow) in acc.iter().enumerate() {
                for (jj, &accv) in accrow.iter().enumerate() {
                    let mut s = hsum256(accv);
                    for kt in kk..fan_in {
                        s += input[in0 + ii * instr + kt] * w[w0 + jj * wstr + kt];
                    }
                    orows[o0 + ii * ostr + jj] = s + bias[bias0 + jj];
                }
            }
        }
    }

    /// Packed block-diagonal product, threaded over batch rows.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn block_diag(
        input: &[f32],
        w: &[f32],
        bias: &[f32],
        out: &mut [f32],
        rows: usize,
        w_in: usize,
        w_out: usize,
        bd: &BlockDiag<'_>,
        threads: usize,
    ) {
        let op = SendPtr(out.as_mut_ptr());
        parallel_chunks(rows, threads, MR, move |r0, r1| {
            debug_assert!(r0 % MR == 0, "simd block_diag chunk start {r0} off the MR={MR} grid");
            // SAFETY: batch rows [r0, r1) are owned by this chunk
            let orows = unsafe {
                std::slice::from_raw_parts_mut(op.ptr().add(r0 * w_out), (r1 - r0) * w_out)
            };
            for (m, &(is, ie)) in bd.spans_in.iter().enumerate() {
                let Some(off) = bd.offs[m] else { continue };
                let (os, oe) = bd.spans_out[m];
                let fan_in = ie - is;
                let mut bi = r0;
                while bi + MR <= r1 {
                    let mut col = os;
                    while col + SIMD_NT_COLS <= oe {
                        // SAFETY: geometry validated by the dispatcher
                        // (spans in bounds, packed rows within w)
                        unsafe {
                            bd_tile(
                                input,
                                bi * w_in + is,
                                w_in,
                                w,
                                off + (col - os) * fan_in,
                                fan_in,
                                bias,
                                col,
                                orows,
                                (bi - r0) * w_out + col,
                                w_out,
                                fan_in,
                            );
                        }
                        col += SIMD_NT_COLS;
                    }
                    blocked::edge_block(
                        input,
                        w,
                        bias,
                        orows,
                        r0,
                        bi,
                        bi + MR,
                        col,
                        oe,
                        is,
                        ie,
                        off,
                        os,
                        w_in,
                        w_out,
                    );
                    bi += MR;
                }
                blocked::edge_block(
                    input, w, bias, orows, r0, bi, r1, os, oe, is, ie, off, os, w_in, w_out,
                );
            }
        });
    }
}
