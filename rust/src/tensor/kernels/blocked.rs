//! The cache-blocked, register-tiled kernels — the hot path.
//!
//! Structure (per orientation):
//!
//! * threads partition **output rows** (`parallel_chunks`), so no
//!   element's reduction ever crosses a thread;
//! * within a thread: `NC`-wide output-column panels, `KC`-deep
//!   reduction slices (the cache blocking — the B panel of one
//!   `(NC, KC)` block stays resident while every row tile streams over
//!   it);
//! * within a block: a `MR×NR` (4×8) register micro-kernel with a
//!   4-way unrolled k-loop — 32 independent accumulator chains give the
//!   FP pipes ILP without reassociating any single element's sum.
//!
//! Exactness (the contract in `mod.rs`): each output element keeps ONE
//! accumulator. Cache blocking splits `k` into `KC` slices, but the
//! running sum parks in `C` between slices and slices are visited in
//! ascending order, so the element's addition sequence is identical to
//! the naive oracle's — bit-for-bit, for every tile size and thread
//! count. The unrolled k-loop performs the same additions in the same
//! order (unrolling a single-accumulator chain does not reorder it).

use super::{BlockDiag, Tile, MR, NR};
use crate::util::threadpool::{parallel_chunks, SendPtr};

/// `acc[ii][jj] += Σ_{kk in k0..k1} a[a0 + ii·astr + kk] · b[b0 + jj·bstr + kk]`
/// — the dot-rows micro-kernel shared by `nt` (both operands row-major
/// along `k`) and the packed block-diagonal product.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn micro_dotrows(
    a: &[f32],
    a0: usize,
    astr: usize,
    b: &[f32],
    b0: usize,
    bstr: usize,
    acc: &mut [[f32; NR]; MR],
    k0: usize,
    k1: usize,
) {
    debug_assert!(a0 + (MR - 1) * astr + k1 <= a.len() + usize::from(k1 == 0));
    debug_assert!(b0 + (NR - 1) * bstr + k1 <= b.len() + usize::from(k1 == 0));
    macro_rules! step {
        ($kk:expr) => {{
            let kk = $kk;
            let mut bv = [0.0f32; NR];
            for (jj, v) in bv.iter_mut().enumerate() {
                // SAFETY: the drivers only call with full MR×NR tiles and
                // k1 within bounds (debug-asserted above)
                *v = unsafe { *b.get_unchecked(b0 + jj * bstr + kk) };
            }
            for (ii, accrow) in acc.iter_mut().enumerate() {
                // SAFETY: same driver guarantee, A side (debug-asserted above)
                let av = unsafe { *a.get_unchecked(a0 + ii * astr + kk) };
                for (cell, &bvj) in accrow.iter_mut().zip(&bv) {
                    *cell += av * bvj;
                }
            }
        }};
    }
    let mut kk = k0;
    while kk + 4 <= k1 {
        step!(kk);
        step!(kk + 1);
        step!(kk + 2);
        step!(kk + 3);
        kk += 4;
    }
    while kk < k1 {
        step!(kk);
        kk += 1;
    }
}

/// `acc[ii][jj] += Σ a[(i+ii)·k + kk] · b[kk·n + j+jj]` — the NN
/// micro-kernel (B is `k`-major; its `NR` lane is contiguous).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn micro_nn(
    a: &[f32],
    b: &[f32],
    acc: &mut [[f32; NR]; MR],
    i: usize,
    j: usize,
    k: usize,
    n: usize,
    k0: usize,
    k1: usize,
) {
    macro_rules! step {
        ($kk:expr) => {{
            let kk = $kk;
            let mut bv = [0.0f32; NR];
            bv.copy_from_slice(&b[kk * n + j..kk * n + j + NR]);
            for (ii, accrow) in acc.iter_mut().enumerate() {
                // SAFETY: drivers guarantee i+MR <= m and kk < k
                let av = unsafe { *a.get_unchecked((i + ii) * k + kk) };
                for (cell, &bvj) in accrow.iter_mut().zip(&bv) {
                    *cell += av * bvj;
                }
            }
        }};
    }
    let mut kk = k0;
    while kk + 4 <= k1 {
        step!(kk);
        step!(kk + 1);
        step!(kk + 2);
        step!(kk + 3);
        kk += 4;
    }
    while kk < k1 {
        step!(kk);
        kk += 1;
    }
}

/// `acc[ii][jj] += Σ a[kk·m + i+ii] · b[kk·n + j+jj]` — the TN
/// micro-kernel (both operands `k`-major; a rank-1 update per `kk`).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn micro_tn(
    a: &[f32],
    b: &[f32],
    acc: &mut [[f32; NR]; MR],
    i: usize,
    j: usize,
    m: usize,
    n: usize,
    k0: usize,
    k1: usize,
) {
    macro_rules! step {
        ($kk:expr) => {{
            let kk = $kk;
            let mut bv = [0.0f32; NR];
            bv.copy_from_slice(&b[kk * n + j..kk * n + j + NR]);
            let arow = &a[kk * m + i..kk * m + i + MR];
            for (accrow, &av) in acc.iter_mut().zip(arow) {
                for (cell, &bvj) in accrow.iter_mut().zip(&bv) {
                    *cell += av * bvj;
                }
            }
        }};
    }
    let mut kk = k0;
    while kk + 4 <= k1 {
        step!(kk);
        step!(kk + 1);
        step!(kk + 2);
        step!(kk + 3);
        kk += 4;
    }
    while kk < k1 {
        step!(kk);
        kk += 1;
    }
}

/// Load an `MR×NR` accumulator tile from a C row slab (rows relative to
/// the slab origin).
#[inline(always)]
fn load_acc(crows: &[f32], row0: usize, j: usize, n: usize) -> [[f32; NR]; MR] {
    let mut acc = [[0.0f32; NR]; MR];
    for (ii, accrow) in acc.iter_mut().enumerate() {
        let base = (row0 + ii) * n + j;
        accrow.copy_from_slice(&crows[base..base + NR]);
    }
    acc
}

/// Store an accumulator tile back into the slab.
#[inline(always)]
fn store_acc(crows: &mut [f32], row0: usize, j: usize, n: usize, acc: &[[f32; NR]; MR]) {
    for (ii, accrow) in acc.iter().enumerate() {
        let base = (row0 + ii) * n + j;
        crows[base..base + NR].copy_from_slice(accrow);
    }
}

/// `C[m,n] = A[m,k] · B[n,k]ᵀ`.
#[allow(clippy::too_many_arguments)]
pub(super) fn nt(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    tile: Tile,
    threads: usize,
) {
    let cp = SendPtr(c.as_mut_ptr());
    let nc = tile.nc.max(NR);
    let kc = tile.kc.max(1);
    parallel_chunks(m, threads, MR, move |r0, r1| {
        debug_assert!(r0 % MR == 0, "nt chunk start {r0} off the MR={MR} grid");
        // SAFETY: rows [r0, r1) are owned exclusively by this chunk
        let crows =
            unsafe { std::slice::from_raw_parts_mut(cp.ptr().add(r0 * n), (r1 - r0) * n) };
        crows.iter_mut().for_each(|x| *x = 0.0);
        let mut jc = 0;
        while jc < n {
            let jend = (jc + nc).min(n);
            let mut ks = 0;
            while ks < k.max(1) {
                let kend = (ks + kc).min(k);
                let mut i = r0;
                while i + MR <= r1 {
                    let mut j = jc;
                    while j + NR <= jend {
                        let mut acc = load_acc(crows, i - r0, j, n);
                        micro_dotrows(a, i * k, k, b, j * k, k, &mut acc, ks, kend);
                        store_acc(crows, i - r0, j, n, &acc);
                        j += NR;
                    }
                    edge_nt(a, b, crows, r0, i, i + MR, j, jend, ks, kend, k, n);
                    i += MR;
                }
                edge_nt(a, b, crows, r0, i, r1, jc, jend, ks, kend, k, n);
                ks = kend.max(ks + 1);
            }
            jc = jend;
        }
    });
}

/// Scalar edge path for NT: accumulate `kk in k0..k1` onto the partial
/// sums already parked in the slab (same order as the micro-kernel).
#[allow(clippy::too_many_arguments)]
pub(super) fn edge_nt(
    a: &[f32],
    b: &[f32],
    crows: &mut [f32],
    r0: usize,
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    k0: usize,
    k1: usize,
    k: usize,
    n: usize,
) {
    // tile extents must stay inside the operands and the row slab: an
    // edge call with i1/j1/k1 past the logical shape would read stale
    // memory silently in release builds
    debug_assert!(i0 >= r0 && j1 <= n && k1 <= k);
    debug_assert!(i1 == i0 || (i1 - r0) * n <= crows.len());
    debug_assert!(i1 == i0 || i1 * k <= a.len() + usize::from(k == 0));
    for i in i0..i1 {
        for j in j0..j1 {
            let mut acc = crows[(i - r0) * n + j];
            for kk in k0..k1 {
                acc += a[i * k + kk] * b[j * k + kk];
            }
            crows[(i - r0) * n + j] = acc;
        }
    }
}

/// `C[m,n] = A[m,k] · B[k,n]`.
#[allow(clippy::too_many_arguments)]
pub(super) fn nn(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    tile: Tile,
    threads: usize,
) {
    let cp = SendPtr(c.as_mut_ptr());
    let nc = tile.nc.max(NR);
    let kc = tile.kc.max(1);
    parallel_chunks(m, threads, MR, move |r0, r1| {
        // chunk starts must sit on the MR grid or rows would switch
        // between tile and edge paths with the thread count (PR-8 bug)
        debug_assert!(r0 % MR == 0, "nn chunk start {r0} off the MR={MR} grid");
        // SAFETY: rows [r0, r1) are owned exclusively by this chunk
        let crows =
            unsafe { std::slice::from_raw_parts_mut(cp.ptr().add(r0 * n), (r1 - r0) * n) };
        crows.iter_mut().for_each(|x| *x = 0.0);
        let mut jc = 0;
        while jc < n {
            let jend = (jc + nc).min(n);
            let mut ks = 0;
            while ks < k.max(1) {
                let kend = (ks + kc).min(k);
                let mut i = r0;
                while i + MR <= r1 {
                    let mut j = jc;
                    while j + NR <= jend {
                        let mut acc = load_acc(crows, i - r0, j, n);
                        micro_nn(a, b, &mut acc, i, j, k, n, ks, kend);
                        store_acc(crows, i - r0, j, n, &acc);
                        j += NR;
                    }
                    edge_nn(a, b, crows, r0, i, i + MR, j, jend, ks, kend, k, n);
                    i += MR;
                }
                edge_nn(a, b, crows, r0, i, r1, jc, jend, ks, kend, k, n);
                ks = kend.max(ks + 1);
            }
            jc = jend;
        }
    });
}

#[allow(clippy::too_many_arguments)]
pub(super) fn edge_nn(
    a: &[f32],
    b: &[f32],
    crows: &mut [f32],
    r0: usize,
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    k0: usize,
    k1: usize,
    k: usize,
    n: usize,
) {
    debug_assert!(i0 >= r0 && j1 <= n && k1 <= k);
    debug_assert!(i1 == i0 || (i1 - r0) * n <= crows.len());
    for i in i0..i1 {
        for j in j0..j1 {
            let mut acc = crows[(i - r0) * n + j];
            for kk in k0..k1 {
                acc += a[i * k + kk] * b[kk * n + j];
            }
            crows[(i - r0) * n + j] = acc;
        }
    }
}

/// `C[m,n] = A[k,m]ᵀ · B[k,n]`.
#[allow(clippy::too_many_arguments)]
pub(super) fn tn(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    tile: Tile,
    threads: usize,
) {
    let cp = SendPtr(c.as_mut_ptr());
    let nc = tile.nc.max(NR);
    let kc = tile.kc.max(1);
    parallel_chunks(m, threads, MR, move |r0, r1| {
        debug_assert!(r0 % MR == 0, "tn chunk start {r0} off the MR={MR} grid");
        // SAFETY: rows [r0, r1) are owned exclusively by this chunk
        let crows =
            unsafe { std::slice::from_raw_parts_mut(cp.ptr().add(r0 * n), (r1 - r0) * n) };
        crows.iter_mut().for_each(|x| *x = 0.0);
        let mut jc = 0;
        while jc < n {
            let jend = (jc + nc).min(n);
            let mut ks = 0;
            while ks < k.max(1) {
                let kend = (ks + kc).min(k);
                let mut i = r0;
                while i + MR <= r1 {
                    let mut j = jc;
                    while j + NR <= jend {
                        let mut acc = load_acc(crows, i - r0, j, n);
                        micro_tn(a, b, &mut acc, i, j, m, n, ks, kend);
                        store_acc(crows, i - r0, j, n, &acc);
                        j += NR;
                    }
                    edge_tn(a, b, crows, r0, i, i + MR, j, jend, ks, kend, m, n);
                    i += MR;
                }
                edge_tn(a, b, crows, r0, i, r1, jc, jend, ks, kend, m, n);
                ks = kend.max(ks + 1);
            }
            jc = jend;
        }
    });
}

#[allow(clippy::too_many_arguments)]
pub(super) fn edge_tn(
    a: &[f32],
    b: &[f32],
    crows: &mut [f32],
    r0: usize,
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    k0: usize,
    k1: usize,
    m: usize,
    n: usize,
) {
    debug_assert!(i0 >= r0 && i1 <= m && j1 <= n);
    debug_assert!(i1 == i0 || (i1 - r0) * n <= crows.len());
    debug_assert!(k1 == k0 || k1 * n <= b.len() + n);
    for i in i0..i1 {
        for j in j0..j1 {
            let mut acc = crows[(i - r0) * n + j];
            for kk in k0..k1 {
                acc += a[kk * m + i] * b[kk * n + j];
            }
            crows[(i - r0) * n + j] = acc;
        }
    }
}

/// Packed block-diagonal product (see [`BlockDiag`]): per model block an
/// NT-shaped product reusing the dot-rows micro-kernel, threaded over
/// batch rows. Blocks are small (one model's fan-in/out), so there is no
/// k-blocking — a single ascending pass per element, bias added once at
/// the end, exactly like the naive oracle.
#[allow(clippy::too_many_arguments)]
pub(super) fn block_diag(
    input: &[f32],
    w: &[f32],
    bias: &[f32],
    out: &mut [f32],
    rows: usize,
    w_in: usize,
    w_out: usize,
    bd: &BlockDiag<'_>,
    threads: usize,
) {
    let op = SendPtr(out.as_mut_ptr());
    parallel_chunks(rows, threads, MR, move |r0, r1| {
        debug_assert!(r0 % MR == 0, "block_diag chunk start {r0} off the MR={MR} grid");
        // SAFETY: batch rows [r0, r1) are owned by this chunk
        let orows =
            unsafe { std::slice::from_raw_parts_mut(op.ptr().add(r0 * w_out), (r1 - r0) * w_out) };
        for (m, &(is, ie)) in bd.spans_in.iter().enumerate() {
            let Some(off) = bd.offs[m] else { continue };
            let (os, oe) = bd.spans_out[m];
            let fan_in = ie - is;
            let mut bi = r0;
            while bi + MR <= r1 {
                let mut col = os;
                while col + NR <= oe {
                    let mut acc = [[0.0f32; NR]; MR];
                    micro_dotrows(
                        input,
                        bi * w_in + is,
                        w_in,
                        w,
                        off + (col - os) * fan_in,
                        fan_in,
                        &mut acc,
                        0,
                        fan_in,
                    );
                    for (ii, accrow) in acc.iter().enumerate() {
                        let base = (bi - r0 + ii) * w_out + col;
                        for (jj, &cell) in accrow.iter().enumerate() {
                            orows[base + jj] = cell + bias[col + jj];
                        }
                    }
                    col += NR;
                }
                edge_block(input, w, bias, orows, r0, bi, bi + MR, col, oe, is, ie, off, os, w_in, w_out);
                bi += MR;
            }
            edge_block(input, w, bias, orows, r0, bi, r1, os, oe, is, ie, off, os, w_in, w_out);
        }
    });
}

/// Scalar edge path for the block-diagonal kernel (rows `i0..i1`, output
/// columns `j0..j1` of one model block).
#[allow(clippy::too_many_arguments)]
pub(super) fn edge_block(
    input: &[f32],
    w: &[f32],
    bias: &[f32],
    orows: &mut [f32],
    r0: usize,
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    is: usize,
    ie: usize,
    off: usize,
    os: usize,
    w_in: usize,
    w_out: usize,
) {
    let fan_in = ie - is;
    debug_assert!(i0 >= r0 && is <= ie && j0 >= os && j1 <= w_out);
    debug_assert!(i1 == i0 || (i1 - r0) * w_out <= orows.len());
    debug_assert!(j1 == j0 || off + (j1 - os) * fan_in <= w.len() + usize::from(fan_in == 0));
    for bi in i0..i1 {
        let irow = &input[bi * w_in + is..bi * w_in + ie];
        for col in j0..j1 {
            let wrow = &w[off + (col - os) * fan_in..off + (col - os + 1) * fan_in];
            orows[(bi - r0) * w_out + col] = super::dot_in_order(irow, wrow) + bias[col];
        }
    }
}
