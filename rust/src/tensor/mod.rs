//! Dense f32 tensor substrate for the native engines.
//!
//! Row-major, CPU-only, deliberately small: the three matmul variants the
//! MLP fwd/bwd needs (`NT`, `NN`, `TN`), broadcastable elementwise helpers
//! and the paper's Scatter-Add. Loops are written so LLVM autovectorizes
//! them (`-C target-cpu=native`); blocking/threading lives in `matmul.rs`.
pub mod matmul;
pub mod scatter;

mod dense;

pub use dense::Tensor;
