//! Dense f32 tensor substrate for the native engines.
//!
//! Row-major, CPU-only, deliberately small: the three matmul variants the
//! MLP fwd/bwd needs (`NT`, `NN`, `TN`), broadcastable elementwise helpers
//! and the paper's Scatter-Add. The matmul implementations live in the
//! [`kernels`] subsystem (a naive reference oracle plus a cache-blocked,
//! register-tiled hot path behind one dispatch enum); [`matmul`] is the
//! thin facade consumers call.
pub mod kernels;
pub mod matmul;
pub mod scatter;

mod dense;

pub use dense::Tensor;
