//! Blocked, threaded matmul kernels — the native engines' MXU.
//!
//! Three orientation variants cover every product the MLP needs without
//! ever materializing a transpose:
//!
//! * `nt`: `C[m,n] = A[m,k] · B[n,k]ᵀ` — forward projections (`X·W1ᵀ`)
//! * `nn`: `C[m,n] = A[m,k] · B[k,n]`  — backward data grads (`dY·W2`)
//! * `tn`: `C[m,n] = A[k,m]ᵀ · B[k,n]` — weight grads (`dHᵀ·X`)
//!
//! Inner loops are contiguous-slice dot/axpy so LLVM autovectorizes them;
//! threading splits output rows (nt/nn) or uses per-thread accumulators
//! (tn, whose k-loop crosses thread boundaries otherwise).

use super::Tensor;
use crate::util::threadpool::{parallel_chunks, SendPtr};

/// Unrolled dot product over two contiguous slices.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4 independent accumulators: break the fp dependency chain so the
    // compiler can keep several FMA pipes busy.
    let chunks = a.len() / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let o = i * 8;
        s0 += a[o] * b[o] + a[o + 4] * b[o + 4];
        s1 += a[o + 1] * b[o + 1] + a[o + 5] * b[o + 5];
        s2 += a[o + 2] * b[o + 2] + a[o + 6] * b[o + 6];
        s3 += a[o + 3] * b[o + 3] + a[o + 7] * b[o + 7];
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..a.len() {
        tail += a[i] * b[i];
    }
    s0 + s1 + s2 + s3 + tail
}

/// `y += alpha * x` over contiguous slices.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `C[m,n] = A[m,k] · B[n,k]ᵀ`, threaded over rows of C.
pub fn matmul_nt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, threads: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(c.len(), m * n);
    let cp = SendPtr(c.as_mut_ptr());
    parallel_chunks(m, threads, 8, move |r0, r1| {
        for i in r0..r1 {
            let arow = &a[i * k..(i + 1) * k];
            // SAFETY: rows [r0, r1) are owned exclusively by this chunk
            let crow = unsafe { std::slice::from_raw_parts_mut(cp.ptr().add(i * n), n) };
            for (j, cv) in crow.iter_mut().enumerate() {
                *cv = dot(arow, &b[j * k..(j + 1) * k]);
            }
        }
    });
}

/// `C[m,n] = A[m,k] · B[k,n]`, threaded over rows of C.
pub fn matmul_nn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, threads: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let cp = SendPtr(c.as_mut_ptr());
    parallel_chunks(m, threads, 8, move |r0, r1| {
        for i in r0..r1 {
            let crow = unsafe { std::slice::from_raw_parts_mut(cp.ptr().add(i * n), n) };
            crow.iter_mut().for_each(|x| *x = 0.0);
            let arow = &a[i * k..(i + 1) * k];
            for (kk, &av) in arow.iter().enumerate() {
                if av != 0.0 {
                    axpy(av, &b[kk * n..(kk + 1) * n], crow);
                }
            }
        }
    });
}

/// `C[m,n] = A[k,m]ᵀ · B[k,n]`, threaded over columns-of-A chunks (each
/// thread owns a disjoint row range of C).
pub fn matmul_tn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, threads: usize) {
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let cp = SendPtr(c.as_mut_ptr());
    parallel_chunks(m, threads, 8, move |m0, m1| {
        // zero this thread's C rows
        for i in m0..m1 {
            let crow = unsafe { std::slice::from_raw_parts_mut(cp.ptr().add(i * n), n) };
            crow.iter_mut().for_each(|x| *x = 0.0);
        }
        for kk in 0..k {
            let brow = &b[kk * n..(kk + 1) * n];
            let arow = &a[kk * m..(kk + 1) * m];
            for i in m0..m1 {
                let av = arow[i];
                if av != 0.0 {
                    let crow = unsafe { std::slice::from_raw_parts_mut(cp.ptr().add(i * n), n) };
                    axpy(av, brow, crow);
                }
            }
        }
    });
}

/// Tensor-level wrappers (allocate the output).
pub fn nt(a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let n = b.rows();
    assert_eq!(b.cols(), k, "nt: inner dims {k} vs {}", b.cols());
    let mut c = Tensor::zeros(&[m, n]);
    matmul_nt(a.data(), b.data(), c.data_mut(), m, k, n, threads);
    c
}

pub fn nn(a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), k, "nn: inner dims {k} vs {}", b.rows());
    let mut c = Tensor::zeros(&[m, n]);
    matmul_nn(a.data(), b.data(), c.data_mut(), m, k, n, threads);
    c
}

pub fn tn(a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    let (k, m) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), k, "tn: inner dims {k} vs {}", b.rows());
    let mut c = Tensor::zeros(&[m, n]);
    matmul_tn(a.data(), b.data(), c.data_mut(), m, k, n, threads);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_nt(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.rows(), a.cols(), b.rows());
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.at2(i, kk) * b.at2(j, kk);
                }
                c.set2(i, j, s);
            }
        }
        c
    }

    fn rand_t(rng: &mut Rng, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(t.data_mut(), 0.0, 1.0);
        t
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(1);
        for len in [0, 1, 3, 7, 8, 9, 31, 64, 100] {
            let mut a = vec![0.0; len];
            let mut b = vec![0.0; len];
            rng.fill_normal(&mut a, 0.0, 1.0);
            rng.fill_normal(&mut b, 0.0, 1.0);
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - want).abs() < 1e-3, "len={len}");
        }
    }

    #[test]
    fn nt_matches_naive() {
        let mut rng = Rng::new(2);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (8, 16, 4), (17, 33, 9), (64, 10, 64)] {
            let a = rand_t(&mut rng, &[m, k]);
            let b = rand_t(&mut rng, &[n, k]);
            for threads in [1, 4] {
                let c = nt(&a, &b, threads);
                assert!(c.max_abs_diff(&naive_nt(&a, &b)) < 1e-4, "{m}x{k}x{n} t={threads}");
            }
        }
    }

    #[test]
    fn nn_matches_nt_of_transpose() {
        let mut rng = Rng::new(3);
        let (m, k, n) = (9, 13, 6);
        let a = rand_t(&mut rng, &[m, k]);
        let b = rand_t(&mut rng, &[k, n]);
        // build bT and compare against nt
        let mut bt = Tensor::zeros(&[n, k]);
        for i in 0..k {
            for j in 0..n {
                bt.set2(j, i, b.at2(i, j));
            }
        }
        for threads in [1, 3] {
            let c = nn(&a, &b, threads);
            assert!(c.max_abs_diff(&naive_nt(&a, &bt)) < 1e-4);
        }
    }

    #[test]
    fn tn_matches_explicit_transpose() {
        let mut rng = Rng::new(4);
        let (k, m, n) = (11, 7, 5);
        let a = rand_t(&mut rng, &[k, m]);
        let b = rand_t(&mut rng, &[k, n]);
        let mut at = Tensor::zeros(&[m, k]);
        for i in 0..k {
            for j in 0..m {
                at.set2(j, i, a.at2(i, j));
            }
        }
        let mut bt = Tensor::zeros(&[n, k]);
        for i in 0..k {
            for j in 0..n {
                bt.set2(j, i, b.at2(i, j));
            }
        }
        for threads in [1, 4] {
            let c = tn(&a, &b, threads);
            assert!(c.max_abs_diff(&naive_nt(&at, &bt)) < 1e-4);
        }
    }

    #[test]
    fn identity_roundtrip() {
        let mut eye = Tensor::zeros(&[4, 4]);
        for i in 0..4 {
            eye.set2(i, i, 1.0);
        }
        let mut rng = Rng::new(5);
        let x = rand_t(&mut rng, &[4, 4]);
        let y = nn(&x, &eye, 1);
        assert!(y.max_abs_diff(&x) < 1e-6);
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 4]);
        nt(&a, &b, 1); // inner dims 3 vs 4
    }
}
