//! Thin matmul facade over the [`crate::tensor::kernels`] subsystem.
//!
//! Three orientation variants cover every product the MLP needs without
//! ever materializing a transpose:
//!
//! * `nt`: `C[m,n] = A[m,k] · B[n,k]ᵀ` — forward projections (`X·W1ᵀ`)
//! * `nn`: `C[m,n] = A[m,k] · B[k,n]`  — backward data grads (`dY·W2`)
//! * `tn`: `C[m,n] = A[k,m]ᵀ · B[k,n]` — weight grads (`dHᵀ·X`)
//!
//! Which implementation executes (the naive reference oracle or the
//! cache-blocked, register-tiled kernel) is decided by the kernel
//! subsystem — process-wide via `PMLP_KERNEL` for the plain functions
//! here, or per call via the `*_with` variants. Both kernels follow the
//! same exactness contract (single-accumulator, `k` ascending per
//! element), so this choice never changes results, only speed.
//!
//! Every entry point comes in two flavors with identical shape checks:
//! `try_*` returns a typed [`ShapeError`]; the panicking twin unwraps it
//! with the same op-tagged message. `dot`/`axpy` remain here as the
//! reassociated (multi-accumulator) primitives the M3 segmented
//! reduction and the stack backward passes stream through — they are
//! NOT part of the kernel exactness contract.

use super::kernels::{self, KernelConfig, ShapeError};
use super::Tensor;

/// Unrolled dot product over two contiguous slices.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4 independent accumulators: break the fp dependency chain so the
    // compiler can keep several FMA pipes busy.
    let chunks = a.len() / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let o = i * 8;
        s0 += a[o] * b[o] + a[o + 4] * b[o + 4];
        s1 += a[o + 1] * b[o + 1] + a[o + 5] * b[o + 5];
        s2 += a[o + 2] * b[o + 2] + a[o + 6] * b[o + 6];
        s3 += a[o + 3] * b[o + 3] + a[o + 7] * b[o + 7];
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..a.len() {
        tail += a[i] * b[i];
    }
    s0 + s1 + s2 + s3 + tail
}

/// `y += alpha * x` over contiguous slices.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

// ---------------------------------------------------------------------------
// Raw-slice entry points
// ---------------------------------------------------------------------------

/// `C[m,n] = A[m,k] · B[n,k]ᵀ` under the process-wide kernel; typed
/// error on any dimension mismatch.
pub fn try_matmul_nt(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Result<(), ShapeError> {
    kernels::matmul_nt_with(kernels::active(), a, b, c, m, k, n, threads)
}

/// Panicking twin of [`try_matmul_nt`] (same checks, same message).
pub fn matmul_nt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, threads: usize) {
    try_matmul_nt(a, b, c, m, k, n, threads).unwrap_or_else(|e| panic!("{e}"));
}

/// `C[m,n] = A[m,k] · B[k,n]` under the process-wide kernel.
pub fn try_matmul_nn(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Result<(), ShapeError> {
    kernels::matmul_nn_with(kernels::active(), a, b, c, m, k, n, threads)
}

/// Panicking twin of [`try_matmul_nn`].
pub fn matmul_nn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, threads: usize) {
    try_matmul_nn(a, b, c, m, k, n, threads).unwrap_or_else(|e| panic!("{e}"));
}

/// `C[m,n] = A[k,m]ᵀ · B[k,n]` under the process-wide kernel.
pub fn try_matmul_tn(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Result<(), ShapeError> {
    kernels::matmul_tn_with(kernels::active(), a, b, c, m, k, n, threads)
}

/// Panicking twin of [`try_matmul_tn`].
pub fn matmul_tn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, threads: usize) {
    try_matmul_tn(a, b, c, m, k, n, threads).unwrap_or_else(|e| panic!("{e}"));
}

// ---------------------------------------------------------------------------
// Tensor-level entry points (allocate the output)
// ---------------------------------------------------------------------------

/// `A[m,k] · B[n,k]ᵀ` under an explicit kernel config.
pub fn try_nt_with(
    cfg: KernelConfig,
    a: &Tensor,
    b: &Tensor,
    threads: usize,
) -> Result<Tensor, ShapeError> {
    let (m, k) = (a.rows(), a.cols());
    let n = b.rows();
    let mut c = Tensor::zeros(&[m, n]);
    kernels::matmul_nt_with(cfg, a.data(), b.data(), c.data_mut(), m, k, n, threads)?;
    Ok(c)
}

/// Panicking twin of [`try_nt_with`].
pub fn nt_with(cfg: KernelConfig, a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    try_nt_with(cfg, a, b, threads).unwrap_or_else(|e| panic!("{e}"))
}

/// `A[m,k] · B[n,k]ᵀ` under the process-wide kernel.
pub fn try_nt(a: &Tensor, b: &Tensor, threads: usize) -> Result<Tensor, ShapeError> {
    try_nt_with(kernels::active(), a, b, threads)
}

/// Panicking twin of [`try_nt`].
pub fn nt(a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    try_nt(a, b, threads).unwrap_or_else(|e| panic!("{e}"))
}

/// `A[m,k] · B[k,n]` under an explicit kernel config.
pub fn try_nn_with(
    cfg: KernelConfig,
    a: &Tensor,
    b: &Tensor,
    threads: usize,
) -> Result<Tensor, ShapeError> {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    let mut c = Tensor::zeros(&[m, n]);
    kernels::matmul_nn_with(cfg, a.data(), b.data(), c.data_mut(), m, k, n, threads)?;
    Ok(c)
}

/// Panicking twin of [`try_nn_with`].
pub fn nn_with(cfg: KernelConfig, a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    try_nn_with(cfg, a, b, threads).unwrap_or_else(|e| panic!("{e}"))
}

/// `A[m,k] · B[k,n]` under the process-wide kernel.
pub fn try_nn(a: &Tensor, b: &Tensor, threads: usize) -> Result<Tensor, ShapeError> {
    try_nn_with(kernels::active(), a, b, threads)
}

/// Panicking twin of [`try_nn`].
pub fn nn(a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    try_nn(a, b, threads).unwrap_or_else(|e| panic!("{e}"))
}

/// `A[k,m]ᵀ · B[k,n]` under an explicit kernel config.
pub fn try_tn_with(
    cfg: KernelConfig,
    a: &Tensor,
    b: &Tensor,
    threads: usize,
) -> Result<Tensor, ShapeError> {
    let (k, m) = (a.rows(), a.cols());
    let n = b.cols();
    let mut c = Tensor::zeros(&[m, n]);
    kernels::matmul_tn_with(cfg, a.data(), b.data(), c.data_mut(), m, k, n, threads)?;
    Ok(c)
}

/// Panicking twin of [`try_tn_with`].
pub fn tn_with(cfg: KernelConfig, a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    try_tn_with(cfg, a, b, threads).unwrap_or_else(|e| panic!("{e}"))
}

/// `A[k,m]ᵀ · B[k,n]` under the process-wide kernel.
pub fn try_tn(a: &Tensor, b: &Tensor, threads: usize) -> Result<Tensor, ShapeError> {
    try_tn_with(kernels::active(), a, b, threads)
}

/// Panicking twin of [`try_tn`].
pub fn tn(a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    try_tn(a, b, threads).unwrap_or_else(|e| panic!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::kernels::Kernel;
    use crate::util::rng::Rng;

    /// In-order scalar reference — the semantics both kernels implement.
    fn ref_nt(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.rows(), a.cols(), b.rows());
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for kk in 0..k {
                    s += a.at2(i, kk) * b.at2(j, kk);
                }
                c.set2(i, j, s);
            }
        }
        c
    }

    fn rand_t(rng: &mut Rng, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(t.data_mut(), 0.0, 1.0);
        t
    }

    fn bits_equal(a: &Tensor, b: &Tensor) -> bool {
        a.shape() == b.shape()
            && a.data().iter().zip(b.data()).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(1);
        for len in [0, 1, 3, 7, 8, 9, 31, 64, 100] {
            let mut a = vec![0.0; len];
            let mut b = vec![0.0; len];
            rng.fill_normal(&mut a, 0.0, 1.0);
            rng.fill_normal(&mut b, 0.0, 1.0);
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - want).abs() < 1e-3, "len={len}");
        }
    }

    #[test]
    fn nt_matches_in_order_reference_exactly() {
        // the facade result must be bit-identical to the in-order
        // reference whatever kernel PMLP_KERNEL selected — that IS the
        // subsystem's exactness contract
        let mut rng = Rng::new(2);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (8, 16, 4), (17, 33, 9), (64, 10, 64)] {
            let a = rand_t(&mut rng, &[m, k]);
            let b = rand_t(&mut rng, &[n, k]);
            let want = ref_nt(&a, &b);
            for threads in [1, 4] {
                assert!(bits_equal(&nt(&a, &b, threads), &want), "{m}x{k}x{n} t={threads}");
            }
        }
    }

    #[test]
    fn nn_matches_nt_of_transpose() {
        let mut rng = Rng::new(3);
        let (m, k, n) = (9, 13, 6);
        let a = rand_t(&mut rng, &[m, k]);
        let b = rand_t(&mut rng, &[k, n]);
        let mut bt = Tensor::zeros(&[n, k]);
        for i in 0..k {
            for j in 0..n {
                bt.set2(j, i, b.at2(i, j));
            }
        }
        for threads in [1, 3] {
            let c = nn(&a, &b, threads);
            assert!(bits_equal(&c, &ref_nt(&a, &bt)));
        }
    }

    #[test]
    fn tn_matches_explicit_transpose() {
        let mut rng = Rng::new(4);
        let (k, m, n) = (11, 7, 5);
        let a = rand_t(&mut rng, &[k, m]);
        let b = rand_t(&mut rng, &[k, n]);
        let mut at = Tensor::zeros(&[m, k]);
        for i in 0..k {
            for j in 0..m {
                at.set2(j, i, a.at2(i, j));
            }
        }
        let mut bt = Tensor::zeros(&[n, k]);
        for i in 0..k {
            for j in 0..n {
                bt.set2(j, i, b.at2(i, j));
            }
        }
        for threads in [1, 4] {
            let c = tn(&a, &b, threads);
            assert!(bits_equal(&c, &ref_nt(&at, &bt)));
        }
    }

    #[test]
    fn explicit_kernel_variants_agree_with_facade() {
        let mut rng = Rng::new(6);
        let a = rand_t(&mut rng, &[13, 21]);
        let b = rand_t(&mut rng, &[17, 21]);
        let via_facade = nt(&a, &b, 2);
        for kernel in [Kernel::Naive, Kernel::Blocked] {
            let cfg = kernels::active().with_kernel(kernel);
            assert!(bits_equal(&nt_with(cfg, &a, &b, 2), &via_facade), "{kernel:?}");
        }
    }

    #[test]
    fn identity_roundtrip() {
        let mut eye = Tensor::zeros(&[4, 4]);
        for i in 0..4 {
            eye.set2(i, i, 1.0);
        }
        let mut rng = Rng::new(5);
        let x = rand_t(&mut rng, &[4, 4]);
        let y = nn(&x, &eye, 1);
        assert!(y.max_abs_diff(&x) < 1e-6);
    }

    // -- dimension mismatches: typed errors and consistent panics ---------

    #[test]
    fn mismatches_yield_typed_errors_for_every_op() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 4]);
        let e = try_nt(&a, &b, 1).unwrap_err();
        assert_eq!(e.op(), "matmul_nt");
        assert!(e.to_string().contains("shape mismatch"), "{e}");

        let b = Tensor::zeros(&[4, 5]); // nn wants [3, n]
        let e = try_nn(&a, &b, 1).unwrap_err();
        assert_eq!(e.op(), "matmul_nn");

        let b = Tensor::zeros(&[3, 5]); // tn wants [2, n] (k = a.rows())
        let e = try_tn(&a, &b, 1).unwrap_err();
        assert_eq!(e.op(), "matmul_tn");

        // raw-slice paths report the offending operand
        let mut c = vec![0.0; 4];
        let e = try_matmul_nt(&[0.0; 5], &[0.0; 6], &mut c, 2, 3, 2, 1).unwrap_err();
        assert!(e.to_string().contains('A'), "{e}");
        let e = try_matmul_nn(&[0.0; 6], &[0.0; 5], &mut c, 2, 3, 2, 1).unwrap_err();
        assert!(e.to_string().contains('B'), "{e}");
        let mut c_bad = vec![0.0; 3];
        let e = try_matmul_tn(&[0.0; 6], &[0.0; 6], &mut c_bad, 2, 3, 2, 1).unwrap_err();
        assert!(e.to_string().contains('C'), "{e}");
    }

    #[test]
    #[should_panic(expected = "matmul_nt: shape mismatch")]
    fn nt_dim_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 4]);
        nt(&a, &b, 1); // inner dims 3 vs 4
    }

    #[test]
    #[should_panic(expected = "matmul_nn: shape mismatch")]
    fn nn_dim_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 5]);
        nn(&a, &b, 1);
    }

    #[test]
    #[should_panic(expected = "matmul_tn: shape mismatch")]
    fn tn_dim_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[3, 5]);
        tn(&a, &b, 1);
    }
}
