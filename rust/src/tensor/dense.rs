//! The `Tensor` type: a row-major f32 buffer with a shape.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor {
    data: Vec<f32>,
    shape: Vec<usize>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 16 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { data: vec![0.0; n], shape: shape.to_vec() }
    }

    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Tensor {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "data length {} != shape {:?}",
            data.len(),
            shape
        );
        Tensor { data, shape: shape.to_vec() }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { data: vec![v], shape: vec![] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// 2D accessor (row, col).
    #[inline]
    pub fn at2(&self, r: usize, c: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[r * self.shape[1] + c]
    }

    #[inline]
    pub fn set2(&mut self, r: usize, c: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[r * self.shape[1] + c] = v;
    }

    /// Row `r` of a 2D tensor.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert_eq!(self.shape.len(), 2);
        let w = self.shape[1];
        &self.data[r * w..(r + 1) * w]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert_eq!(self.shape.len(), 2);
        let w = self.shape[1];
        &mut self.data[r * w..(r + 1) * w]
    }

    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        self.shape[1]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(self.len(), shape.iter().product::<usize>());
        self.shape = shape.to_vec();
        self
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// `self += other` (same shape).
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// `self -= scale * other` — the SGD update.
    pub fn saxpy_neg(&mut self, scale: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a -= scale * b;
        }
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// 3D accessor (i, j, k).
    #[inline]
    pub fn at3(&self, i: usize, j: usize, k: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 3);
        self.data[(i * self.shape[1] + j) * self.shape[2] + k]
    }

    #[inline]
    pub fn set3(&mut self, i: usize, j: usize, k: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 3);
        self.data[(i * self.shape[1] + j) * self.shape[2] + k] = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let mut t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        t.set2(1, 2, 5.0);
        assert_eq!(t.at2(1, 2), 5.0);
        assert_eq!(t.row(1), &[0.0, 0.0, 5.0]);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_mismatch_panics() {
        Tensor::from_vec(vec![1.0, 2.0], &[3]);
    }

    #[test]
    fn saxpy_and_add() {
        let mut a = Tensor::from_vec(vec![1.0, 2.0], &[2]);
        let g = Tensor::from_vec(vec![10.0, 20.0], &[2]);
        a.saxpy_neg(0.1, &g);
        assert_eq!(a.data(), &[0.0, 0.0]);
        a.add_assign(&g);
        assert_eq!(a.data(), &[10.0, 20.0]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec((0..6).map(|i| i as f32).collect(), &[2, 3]);
        let r = t.clone().reshape(&[3, 2]);
        assert_eq!(r.data(), t.data());
        assert_eq!(r.shape(), &[3, 2]);
    }

    #[test]
    fn diff_and_finite() {
        let a = Tensor::from_vec(vec![1.0, 2.0], &[2]);
        let b = Tensor::from_vec(vec![1.5, 1.0], &[2]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
        assert!(a.all_finite());
        let c = Tensor::from_vec(vec![f32::NAN], &[1]);
        assert!(!c.all_finite());
    }

    #[test]
    fn at3_indexing() {
        let mut t = Tensor::zeros(&[2, 3, 4]);
        t.set3(1, 2, 3, 7.0);
        assert_eq!(t.at3(1, 2, 3), 7.0);
        assert_eq!(t.data()[23], 7.0);
    }
}
