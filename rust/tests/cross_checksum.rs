//! Cross-language layout contract: the Rust layout compiler must produce
//! the exact checksums the Python compiler recorded in the live manifest,
//! for every pool — plus golden-value spot checks that don't need
//! artifacts at all.

use std::path::Path;

use parallel_mlps::nn::act::{Act, ALL_ACTS};
use parallel_mlps::pool::{PoolLayout, PoolSpec};
use parallel_mlps::runtime::Manifest;

#[test]
fn live_manifest_checksums_agree() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let Ok(m) = Manifest::load(&dir) else {
        eprintln!("skipping: no artifacts");
        return;
    };
    assert!(!m.pools.is_empty());
    for (name, entry) in &m.pools {
        let lay = PoolLayout::build(&entry.spec);
        assert_eq!(
            lay.checksum(),
            entry.checksum,
            "pool {name}: rust layout checksum != python layout checksum"
        );
        assert_eq!(lay.h_pad(), entry.h_pad, "pool {name}");
        assert_eq!(lay.m_pad(), entry.m_pad, "pool {name}");
        assert_eq!(lay.n_groups, entry.n_groups, "pool {name}");
    }
}

#[test]
fn smoke_pool_structure_matches_specs_py() {
    // mirror of python/compile/specs.py SMOKE_MODELS
    let models = [(2u32, 1u8), (3, 3), (2, 2), (1, 0), (4, 6), (2, 9), (3, 3), (5, 5)];
    let spec = PoolSpec::new(
        models.iter().map(|&(h, a)| (h, Act::from_id(a).unwrap())).collect(),
    )
    .unwrap();
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let Ok(m) = Manifest::load(&dir) else { return };
    let entry = &m.pools["smoke"];
    assert_eq!(entry.spec.models(), spec.models(), "smoke pool drifted from specs.py");
}

#[test]
fn bench_pool_structure_matches_specs_py() {
    let spec = PoolSpec::from_grid(&[2, 4, 8, 16, 25], &ALL_ACTS, 4).unwrap();
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let Ok(m) = Manifest::load(&dir) else { return };
    let entry = &m.pools["bench"];
    assert_eq!(entry.spec.models(), spec.models(), "bench pool drifted from specs.py");
    assert_eq!(entry.spec.n_models(), 200);
}

#[test]
fn e2e_pool_structure_matches_specs_py() {
    let hs: Vec<u32> = (1..=12).collect();
    let spec = PoolSpec::from_grid(&hs, &ALL_ACTS, 1).unwrap();
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let Ok(m) = Manifest::load(&dir) else { return };
    let entry = &m.pools["e2e"];
    assert_eq!(entry.spec.models(), spec.models(), "e2e pool drifted from specs.py");
    assert_eq!(entry.spec.n_models(), 120);
}
