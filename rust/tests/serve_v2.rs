//! Serving-v2 concurrency suite: checkpoint hot-swap atomicity under
//! concurrent clients, bounded-queue shed-load semantics, shard-count
//! bit-invariance, and the HTTP/1.1 front end over a real localhost
//! socket (round-trip, malformed 4xx, oversized 413, graceful drain).
//!
//! The atomicity tests use *integer-weight* generations: generation `g`
//! is a single linear layer with every weight and bias equal to `g`, so
//! for an all-ones input row each logit is exactly `(F + 1) * g` — tiny
//! integers, exact in f32 under the bit-exact kernel tier. Any torn
//! read (a matmul over generation `a` weights finished with generation
//! `b` bias, or a reply tagged with the wrong generation) breaks that
//! identity bit-for-bit.

use std::collections::BTreeSet;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use parallel_mlps::nn::act::Act;
use parallel_mlps::nn::stack::{DenseLayer, DenseStack};
use parallel_mlps::serve::bench::{run_sustained, SustainedSpec};
use parallel_mlps::serve::{
    HttpConfig, HttpServer, ModelSlot, ServableModel, ShardConfig, ShardedServer, SubmitError,
};
use parallel_mlps::tensor::kernels::Kernel;
use parallel_mlps::tensor::Tensor;
use parallel_mlps::util::rng::Rng;

const F: usize = 5;
const O: usize = 3;

/// Generation `g` as a servable: one linear layer, every parameter
/// equal to `g`. For an all-ones row, every logit is `(F + 1) * g`.
fn int_model(g: u64) -> ServableModel {
    let w = Tensor::from_vec(vec![g as f32; O * F], &[O, F]);
    let b = Tensor::from_vec(vec![g as f32; O], &[1, O]);
    ServableModel::new(
        format!("int/gen{g}"),
        g as usize,
        DenseStack { layers: vec![DenseLayer { w, b }], act: Act::Identity },
    )
}

fn cfg(shards: usize, kernel: Kernel) -> ShardConfig {
    ShardConfig { shards, max_batch: 8, queue_cap: 4096, threads: 1, kernel: Some(kernel) }
}

// ---------------------------------------------------------------------
// hot-swap atomicity
// ---------------------------------------------------------------------

#[test]
fn hot_swap_atomicity_under_concurrent_clients() {
    const SWAPS: u64 = 3; // generations 1 -> 4 land mid-traffic
    const CLIENTS: usize = 4;
    let slot = ModelSlot::new(int_model(1));
    let server = Arc::new(ShardedServer::start(slot, cfg(4, Kernel::Naive)).unwrap());

    // clients run for a fixed window that strictly covers all the
    // promotions below, so the swaps genuinely land under live traffic
    let window = Duration::from_millis(150);
    let mut clients = Vec::new();
    for _ in 0..CLIENTS {
        let client = server.client();
        clients.push(std::thread::spawn(move || -> (Vec<u64>, usize) {
            let row = [1.0f32; F];
            let start = Instant::now();
            let mut seen = Vec::new();
            let mut violations = 0usize;
            while start.elapsed() < window {
                let p = client.predict(&row).unwrap();
                // every logit must equal (F+1) * claimed generation —
                // a mixed-generation forward cannot produce this
                let want = (F as f32 + 1.0) * p.generation as f32;
                if p.logits.len() != O || p.logits.iter().any(|l| l.to_bits() != want.to_bits()) {
                    violations += 1;
                }
                seen.push(p.generation);
            }
            (seen, violations)
        }));
    }

    // promote generations 2..=4 while the clients hammer the shards
    for g in 2..=(SWAPS + 1) {
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(server.promote(int_model(g)).unwrap(), g);
    }

    let mut all_gens: BTreeSet<u64> = BTreeSet::new();
    let mut answered = 0usize;
    for c in clients {
        let (seen, violations) = c.join().unwrap();
        assert_eq!(violations, 0, "mixed-generation (torn) responses observed");
        // a client is pinned to one shard whose worker upgrades its
        // snapshot monotonically — generations never go backwards
        assert!(seen.windows(2).all(|w| w[0] <= w[1]), "generation went backwards");
        assert!(!seen.is_empty());
        answered += seen.len();
        all_gens.extend(seen);
    }
    assert!(all_gens.iter().all(|g| (1..=SWAPS + 1).contains(g)));
    // the promotions all landed inside the traffic window: the final
    // generation must have been observed by live clients
    assert!(all_gens.contains(&(SWAPS + 1)), "no client saw the final generation");
    assert_eq!(server.generation(), SWAPS + 1);
    let server = Arc::try_unwrap(server).ok().expect("all clients joined");
    let (totals, _) = server.shutdown();
    assert_eq!(totals.rows, answered);
    assert_eq!(totals.shed, 0);
}

#[test]
fn promotion_is_rejected_not_partially_applied() {
    // a wire-contract-incompatible promotion must leave the old
    // generation fully serving — not a half-installed model
    let slot = ModelSlot::new(int_model(1));
    let server = ShardedServer::start(slot, cfg(2, Kernel::Naive)).unwrap();
    let wrong_width = ServableModel::new(
        "bad",
        9,
        DenseStack {
            layers: vec![DenseLayer {
                w: Tensor::from_vec(vec![7.0; O * (F + 1)], &[O, F + 1]),
                b: Tensor::from_vec(vec![7.0; O], &[1, O]),
            }],
            act: Act::Identity,
        },
    );
    assert!(server.promote(wrong_width).is_err());
    assert_eq!(server.generation(), 1);
    let p = server.client().predict(&[1.0; F]).unwrap();
    assert_eq!(p.generation, 1);
    let want = F as f32 + 1.0;
    assert!(p.logits.iter().all(|l| l.to_bits() == want.to_bits()));
}

// ---------------------------------------------------------------------
// bounded-queue shed-load semantics
// ---------------------------------------------------------------------

#[test]
fn full_queue_sheds_typed_error_and_never_deadlocks() {
    const CAP: usize = 8;
    let slot = ModelSlot::new(int_model(1));
    let config = ShardConfig {
        shards: 1,
        max_batch: 4,
        queue_cap: CAP,
        threads: 1,
        kernel: Some(Kernel::Naive),
    };
    // workers parked at the gate: the queue can only fill
    let server = Arc::new(ShardedServer::start_held(slot, config).unwrap());

    let client = server.client_for(0);
    let mut accepted = Vec::new();
    for i in 0..CAP {
        accepted.push(client.submit(&[i as f32; F]).unwrap());
    }
    // the queue is now full: every further submit — from any number of
    // concurrent threads — must return Overloaded immediately, never
    // block. A deadlock here would hang the test harness.
    let mut stormers = Vec::new();
    for _ in 0..4 {
        let c = server.client_for(0);
        stormers.push(std::thread::spawn(move || {
            let mut shed = 0usize;
            for _ in 0..50 {
                match c.submit(&[2.0; F]) {
                    Err(SubmitError::Overloaded { shard: 0, queue_cap: CAP }) => shed += 1,
                    Err(e) => panic!("expected Overloaded, got {e:?}"),
                    Ok(_) => panic!("expected Overloaded, got an accepted ticket"),
                }
            }
            shed
        }));
    }
    let shed_total: usize = stormers.into_iter().map(|t| t.join().unwrap()).sum();
    assert_eq!(shed_total, 200);
    assert_eq!(server.queue_depths(), vec![CAP]);

    // release the gate: every ACCEPTED request is answered, correctly
    server.release();
    for (i, t) in accepted.into_iter().enumerate() {
        let p = t.wait().unwrap();
        let want = (F as f32) * i as f32 + 1.0; // i·F weights + bias 1
        assert_eq!(p.generation, 1);
        assert!(p.logits.iter().all(|l| l.to_bits() == want.to_bits()));
    }
    let server = Arc::try_unwrap(server).ok().expect("stormers joined");
    let (totals, _) = server.shutdown();
    assert_eq!(totals.rows, CAP, "exactly the accepted requests were served");
    assert_eq!(totals.shed, 200);
    assert_eq!(totals.max_depth_seen, CAP);
}

#[test]
fn shed_then_recover_accepts_again() {
    let slot = ModelSlot::new(int_model(1));
    let config = ShardConfig {
        shards: 1,
        max_batch: 2,
        queue_cap: 2,
        threads: 1,
        kernel: Some(Kernel::Naive),
    };
    let server = ShardedServer::start_held(slot, config).unwrap();
    let c = server.client_for(0);
    let t0 = c.submit(&[1.0; F]).unwrap();
    let t1 = c.submit(&[1.0; F]).unwrap();
    assert!(matches!(c.submit(&[1.0; F]), Err(SubmitError::Overloaded { .. })));
    server.release();
    t0.wait().unwrap();
    t1.wait().unwrap();
    // drained: admission control accepts again — shedding is a state,
    // not a latch
    let p = c.predict(&[1.0; F]).unwrap();
    assert_eq!(p.generation, 1);
}

// ---------------------------------------------------------------------
// shard-count invariance
// ---------------------------------------------------------------------

#[test]
fn predictions_bit_invariant_across_shard_counts() {
    // the same 64 requests through 1, 2 and 8 shards must produce
    // bit-identical predictions under both bit-exact kernels. (simd is
    // excluded by contract: its tile-vs-edge paths depend on a row's
    // position within the coalesced batch, so it is bounded-ulp, not
    // bit-stable, across batch compositions.)
    let mut rng = Rng::new(77);
    let rows: Vec<Vec<f32>> = (0..64)
        .map(|_| {
            let mut r = vec![0.0f32; F];
            for v in r.iter_mut() {
                *v = rng.uniform_in(-2.0, 2.0);
            }
            r
        })
        .collect();
    // reference: one direct forward over the whole set as a batch
    let mut x = Tensor::zeros(&[rows.len(), F]);
    for (i, r) in rows.iter().enumerate() {
        x.row_mut(i).copy_from_slice(r);
    }

    for kernel in [Kernel::Naive, Kernel::Blocked] {
        let model = int_model(3);
        let kcfg = cfg(1, kernel).kernel_config();
        let want = model.predict_with(kcfg, &x, 1);
        for shards in [1usize, 2, 8] {
            let slot = ModelSlot::new(int_model(3));
            let server = ShardedServer::start(slot, cfg(shards, kernel)).unwrap();
            // spread the rows over distinct round-robin clients so the
            // batching pattern genuinely differs per shard count
            let tickets: Vec<_> =
                rows.iter().map(|r| server.client().submit(r).unwrap()).collect();
            for (i, t) in tickets.into_iter().enumerate() {
                let p = t.wait().unwrap();
                let w = want.row(i);
                assert_eq!(p.logits.len(), w.len());
                for (a, b) in p.logits.iter().zip(w) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "row {i} differs at {shards} shards under {kernel:?}"
                    );
                }
            }
            server.shutdown();
        }
    }
}

// ---------------------------------------------------------------------
// sustained load: ≥3 mid-traffic hot-swaps, zero dropped/incorrect
// ---------------------------------------------------------------------

#[test]
fn sustained_load_three_hot_swaps_zero_dropped_zero_incorrect() {
    let generations: Vec<ServableModel> = (1..=4).map(int_model).collect();
    let config = ShardConfig {
        shards: 2,
        max_batch: 8,
        queue_cap: 1024,
        threads: 1,
        kernel: Some(Kernel::Blocked),
    };
    let spec = SustainedSpec {
        duration_s: 0.6,
        rate_rps: 1200.0,
        clients: 3,
        verify: true, // bit-check every response under its claimed generation
        seed: 7,
    };
    let rep = run_sustained(generations, config, &spec).unwrap();
    assert_eq!(rep.swaps, 3);
    assert_eq!(rep.start_generation, 1);
    assert_eq!(rep.end_generation, 4);
    assert_eq!(rep.incorrect, 0);
    assert_eq!(rep.answered + rep.shed, rep.submitted, "no request dropped");
    // generous latency/shed budgets: this asserts correctness-under-swap
    // machinery, not this machine's speed
    rep.check_slo(30_000.0, 0.5, 3).unwrap();
}

// ---------------------------------------------------------------------
// HTTP front end over a real localhost socket
// ---------------------------------------------------------------------

/// Send one HTTP/1.1 request over `stream` and read one full response
/// (status code, body) using its Content-Length — keep-alive safe.
fn roundtrip(stream: &mut TcpStream, raw: &str) -> (u16, String) {
    stream.write_all(raw.as_bytes()).unwrap();
    read_response(stream)
}

fn read_response(stream: &mut TcpStream) -> (u16, String) {
    let mut buf = Vec::new();
    let head_end = loop {
        if let Some(p) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break p;
        }
        let mut chunk = [0u8; 2048];
        let n = stream.read(&mut chunk).unwrap();
        assert!(n > 0, "connection closed before a full response head");
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8(buf[..head_end].to_vec()).unwrap();
    let status: u16 =
        head.split(' ').nth(1).expect("status line").parse().expect("numeric status");
    let content_length: usize = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.trim().eq_ignore_ascii_case("content-length").then(|| v.trim().parse().unwrap())
        })
        .expect("Content-Length header");
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let mut chunk = [0u8; 2048];
        let n = stream.read(&mut chunk).unwrap();
        assert!(n > 0, "connection closed mid-body");
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    (status, String::from_utf8(body).unwrap())
}

fn post_predict(body: &str) -> String {
    format!("POST /predict HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}", body.len())
}

fn start_http(shards: usize) -> (Arc<ShardedServer>, HttpServer) {
    let slot = ModelSlot::new(int_model(1));
    let engine = Arc::new(ShardedServer::start(slot, cfg(shards, Kernel::Naive)).unwrap());
    let http = HttpServer::start(engine.clone(), HttpConfig::default()).unwrap();
    (engine, http)
}

#[test]
fn http_json_round_trip_single_and_batch() {
    let (engine, http) = start_http(2);
    let mut s = TcpStream::connect(http.local_addr()).unwrap();

    // single row: logits must round-trip through JSON bit-exactly
    let (status, body) = roundtrip(&mut s, &post_predict(r#"{"row": [1, 1, 1, 1, 1]}"#));
    assert_eq!(status, 200, "{body}");
    let v = parallel_mlps::util::json::parse(&body).unwrap();
    assert_eq!(v.req("generation").unwrap().as_usize(), Some(1));
    let logits = v.req("logits").unwrap().as_arr().unwrap();
    assert_eq!(logits.len(), O);
    for l in logits {
        assert_eq!(l.as_f64().unwrap() as f32, F as f32 + 1.0);
    }

    // batch rows on the SAME keep-alive connection
    let (status, body) =
        roundtrip(&mut s, &post_predict(r#"{"rows": [[1,1,1,1,1],[2,2,2,2,2]]}"#));
    assert_eq!(status, 200, "{body}");
    let v = parallel_mlps::util::json::parse(&body).unwrap();
    let outs = v.req("outputs").unwrap().as_arr().unwrap();
    assert_eq!(outs.len(), 2);
    assert_eq!(outs[1].as_arr().unwrap()[0].as_f64().unwrap() as f32, 2.0 * F as f32 + 1.0);
    assert_eq!(v.req("generations").unwrap().as_arr().unwrap().len(), 2);

    // healthz + stats
    let (status, body) = roundtrip(&mut s, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
    assert_eq!(status, 200);
    let v = parallel_mlps::util::json::parse(&body).unwrap();
    assert_eq!(v.req("status").unwrap().as_str(), Some("ok"));
    assert_eq!(v.req("shards").unwrap().as_usize(), Some(2));
    let (status, body) = roundtrip(&mut s, "GET /stats HTTP/1.1\r\nHost: t\r\n\r\n");
    assert_eq!(status, 200);
    let v = parallel_mlps::util::json::parse(&body).unwrap();
    assert_eq!(v.req("shards").unwrap().as_arr().unwrap().len(), 2);

    drop(s);
    let hstats = http.shutdown();
    assert_eq!(hstats.client_errors, 0);
    assert!(hstats.requests >= 4);
    drop(engine);
}

#[test]
fn http_hot_swap_visible_in_replies() {
    let (engine, http) = start_http(1);
    let mut s = TcpStream::connect(http.local_addr()).unwrap();
    let (_, body) = roundtrip(&mut s, &post_predict(r#"{"row": [1,1,1,1,1]}"#));
    let v = parallel_mlps::util::json::parse(&body).unwrap();
    assert_eq!(v.req("generation").unwrap().as_usize(), Some(1));
    engine.promote(int_model(2)).unwrap();
    let (_, body) = roundtrip(&mut s, &post_predict(r#"{"row": [1,1,1,1,1]}"#));
    let v = parallel_mlps::util::json::parse(&body).unwrap();
    assert_eq!(v.req("generation").unwrap().as_usize(), Some(2));
    let logits = v.req("logits").unwrap().as_arr().unwrap();
    assert_eq!(logits[0].as_f64().unwrap() as f32, (F as f32 + 1.0) * 2.0);
    drop(s);
    http.shutdown();
    drop(engine);
}

#[test]
fn http_malformed_requests_get_4xx() {
    let (engine, http) = start_http(1);

    // not JSON
    let mut s = TcpStream::connect(http.local_addr()).unwrap();
    let (status, body) = roundtrip(&mut s, &post_predict("{not json"));
    assert_eq!(status, 400);
    assert!(body.contains("invalid JSON"), "{body}");

    // JSON without row/rows (keep-alive: same connection still works)
    let (status, body) = roundtrip(&mut s, &post_predict(r#"{"cols": [1]}"#));
    assert_eq!(status, 400);
    assert!(body.contains("row"), "{body}");

    // wrong feature width is a client error, not a 500
    let (status, body) = roundtrip(&mut s, &post_predict(r#"{"row": [1, 2]}"#));
    assert_eq!(status, 400);
    assert!(body.contains("features"), "{body}");

    // non-numeric row
    let (status, _) = roundtrip(&mut s, &post_predict(r#"{"row": ["a","b","c","d","e"]}"#));
    assert_eq!(status, 400);

    // unknown path / wrong method
    let (status, _) = roundtrip(&mut s, "GET /nope HTTP/1.1\r\nHost: t\r\n\r\n");
    assert_eq!(status, 404);
    let (status, _) = roundtrip(&mut s, "GET /predict HTTP/1.1\r\nHost: t\r\n\r\n");
    assert_eq!(status, 405);
    drop(s);

    // garbage request line closes with 400
    let mut s2 = TcpStream::connect(http.local_addr()).unwrap();
    let (status, _) = roundtrip(&mut s2, "GARBAGE\r\n\r\n");
    assert_eq!(status, 400);
    drop(s2);

    let hstats = http.shutdown();
    assert_eq!(hstats.client_errors, 7);
    drop(engine);
}

#[test]
fn http_oversized_body_is_rejected_without_reading_it() {
    let slot = ModelSlot::new(int_model(1));
    let engine = Arc::new(ShardedServer::start(slot, cfg(1, Kernel::Naive)).unwrap());
    let config = HttpConfig { max_body: 256, ..HttpConfig::default() };
    let http = HttpServer::start(engine.clone(), config).unwrap();

    let mut s = TcpStream::connect(http.local_addr()).unwrap();
    // declare a body far beyond max_body and send NOTHING after the
    // head: the 413 must arrive without the server waiting for a body
    s.write_all(b"POST /predict HTTP/1.1\r\nHost: t\r\nContent-Length: 100000\r\n\r\n")
        .unwrap();
    let (status, body) = read_response(&mut s);
    assert_eq!(status, 413);
    assert!(body.contains("max_body"), "{body}");
    drop(s);

    // a body under the cap is still read and parsed (and 400s on its
    // content — proving the cap, not the parser, rejected the one above)
    let mut s2 = TcpStream::connect(http.local_addr()).unwrap();
    let small = "x".repeat(100);
    let (status, _) = roundtrip(&mut s2, &post_predict(&small));
    assert_eq!(status, 400);
    drop(s2);

    http.shutdown();
    drop(engine);
}

#[test]
fn http_graceful_shutdown_drains_in_flight_requests() {
    // workers held at the gate: an HTTP request gets stuck in-flight;
    // shutdown must WAIT for it (drain), and the reply must be correct
    let slot = ModelSlot::new(int_model(1));
    let config = ShardConfig {
        shards: 1,
        max_batch: 4,
        queue_cap: 16,
        threads: 1,
        kernel: Some(Kernel::Naive),
    };
    let engine = Arc::new(ShardedServer::start_held(slot, config).unwrap());
    let http = HttpServer::start(engine.clone(), HttpConfig::default()).unwrap();
    let addr = http.local_addr();

    let in_flight = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        roundtrip(&mut s, &post_predict(r#"{"row": [1,1,1,1,1]}"#))
    });
    // let the request reach the (held) shard queue
    std::thread::sleep(Duration::from_millis(150));
    // release only after shutdown has begun: if shutdown did not drain,
    // the in-flight client would see a reset instead of its answer
    let releaser = {
        let engine = engine.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(300));
            engine.release();
        })
    };
    let hstats = http.shutdown(); // blocks until the handler drains
    releaser.join().unwrap();
    let (status, body) = in_flight.join().unwrap();
    assert_eq!(status, 200, "in-flight request must be answered through shutdown: {body}");
    let v = parallel_mlps::util::json::parse(&body).unwrap();
    let logits = v.req("logits").unwrap().as_arr().unwrap();
    assert_eq!(logits[0].as_f64().unwrap() as f32, F as f32 + 1.0);
    assert_eq!(hstats.requests, 1);

    // post-shutdown: the listener is gone — a new connection either
    // fails outright or gets no service (EOF/reset, never a response)
    match TcpStream::connect(addr) {
        Err(_) => {}
        Ok(mut s) => {
            s.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
            let _ = s.write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
            let mut b = [0u8; 8];
            match s.read(&mut b) {
                Ok(0) => {}
                Ok(_) => panic!("listener still serving after shutdown"),
                Err(_) => {}
            }
        }
    }
    drop(engine);
}
