//! End-to-end real-dataset acceptance: CSV → k-fold ranking → export →
//! serve. The contract under test is the PR's tentpole guarantee — a
//! served prediction equals an offline forward pass through the SAME
//! persisted preprocessor to within 1e-5, and k-fold ranking is
//! deterministic for a fixed seed.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use parallel_mlps::config::ExperimentConfig;
use parallel_mlps::coordinator::{run_experiment_trained, run_kfold};
use parallel_mlps::data::csv::read_raw;
use parallel_mlps::io::PoolCheckpoint;
use parallel_mlps::nn::act::Act;
use parallel_mlps::nn::loss::Loss;
use parallel_mlps::serve::{ModelRegistry, ServeConfig, Server};
use parallel_mlps::tensor::Tensor;

fn blossom_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("examples/data/blossom.csv")
}

fn blossom_cfg() -> ExperimentConfig {
    ExperimentConfig {
        data_path: Some(blossom_path().to_str().unwrap().to_string()),
        target: Some("species".into()),
        hidden_sizes: vec![2, 4, 8],
        acts: vec![Act::Relu, Act::Tanh],
        epochs: 6,
        warmup_epochs: 1,
        batch: 16,
        lr: 0.1,
        threads: 2,
        seed: 11,
        ..Default::default()
    }
}

#[test]
fn csv_load_resolves_schema() {
    let t = parallel_mlps::data::load_table(&blossom_path(), "species").unwrap();
    assert_eq!(t.dataset.len(), 150);
    // 4 numeric + site one-hot (meadow/ridge/valley) = 7 features
    assert_eq!(t.dataset.features(), 7);
    assert_eq!(t.n_classes(), Some(3));
    assert_eq!(
        t.feature_names,
        vec![
            "sepal_len",
            "sepal_wid",
            "petal_len",
            "petal_wid",
            "site=meadow",
            "site=ridge",
            "site=valley"
        ]
    );
}

#[test]
fn csv_kfold_ranking_is_deterministic() {
    let mut cfg = blossom_cfg();
    cfg.folds = Some(3);
    let (eff, a) = run_kfold(&cfg).unwrap();
    let (_, b) = run_kfold(&cfg).unwrap();
    // the data dictated the task: 3-class CE over 7 features
    assert_eq!(eff.loss, Loss::Ce);
    assert_eq!(eff.features, 7);
    assert_eq!(eff.out, 3);
    assert_eq!(a.folds(), 3);
    assert_eq!(a.fold_sizes.iter().sum::<usize>(), 150);
    assert_eq!(a.ranked.len(), 6);
    for (fa, fb) in a.fold_losses.iter().zip(&b.fold_losses) {
        assert!(fa.iter().zip(fb).all(|(x, y)| x.to_bits() == y.to_bits()));
    }
    let oa: Vec<usize> = a.ranked.iter().map(|r| r.index).collect();
    let ob: Vec<usize> = b.ranked.iter().map(|r| r.index).collect();
    assert_eq!(oa, ob);
    // blossom clusters are separable: the CV winner beats chance
    assert!(a.ranked[0].val_metric > 0.6, "{:?}", a.ranked[0]);
}

#[test]
fn csv_kfold_export_serve_matches_offline_forward() {
    // the full acceptance path: train on the CSV with k-fold ranking,
    // export the pool (preprocessor embedded), reload, serve the winner
    // through the micro-batch server, and compare against an offline
    // forward pass that encodes the same raw rows with the persisted
    // preprocessor
    let mut cfg = blossom_cfg();
    cfg.folds = Some(3);
    let trained = run_experiment_trained(&cfg).unwrap();
    assert_eq!(trained.report.cv_folds, Some(3));
    let pre = trained.preprocessor.clone().expect("CSV runs fit a preprocessor");
    let ckpt = PoolCheckpoint::from_engine(
        trained.engine.as_ref(),
        trained.config.loss,
        &trained.report.ranked,
    )
    .unwrap()
    .with_preprocessor(pre)
    .unwrap();

    let path = std::env::temp_dir().join(format!("pmlp_realdata_{}.ckpt", std::process::id()));
    ckpt.save(&path).unwrap();
    let back = PoolCheckpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let pre = back.preprocessor.clone().expect("preprocessor survives the roundtrip");
    assert_eq!(pre.n_features(), 7);
    assert_eq!(pre.class_names().unwrap(), &["setosa", "versicolor", "virginica"]);

    let mut registry = ModelRegistry::new();
    let names = registry.load_top_k("blossom", &back, 1).unwrap();
    let model = registry.get(&names[0]).unwrap();
    assert_eq!(model.index, trained.report.ranked[0].index);

    // raw rows from the file, re-encoded through the persisted pipeline
    let text = std::fs::read_to_string(blossom_path()).unwrap();
    let (header, raw) = read_raw(&text, "blossom.csv").unwrap();
    let feat_idx: Vec<usize> = pre
        .columns
        .iter()
        .map(|c| header.iter().position(|h| *h == c.name).unwrap())
        .collect();
    let rows: Vec<Vec<f32>> = raw
        .iter()
        .take(32)
        .map(|row| {
            let fields: Vec<&str> = feat_idx.iter().map(|&c| row[c].as_str()).collect();
            pre.encode_row(&fields).unwrap()
        })
        .collect();

    // offline forward over the whole block at once
    let flat: Vec<f32> = rows.iter().flatten().copied().collect();
    let x = Tensor::from_vec(flat, &[rows.len(), pre.n_features()]);
    let offline = model.predict(&x, 1);

    // served micro-batched, single-row requests
    let server = Server::start(
        Arc::clone(&model),
        ServeConfig { max_batch: 8, queue_cap: 64, threads: 1 },
    )
    .unwrap();
    let client = server.client();
    for (i, row) in rows.iter().enumerate() {
        let got = client.predict(row).unwrap();
        for (j, &v) in got.iter().enumerate() {
            let want = offline.at2(i, j);
            assert!(
                (v - want).abs() <= 1e-5,
                "row {i} logit {j}: served {v} vs offline {want}"
            );
        }
    }
    server.shutdown();
}

#[test]
fn regression_csv_roundtrips_under_mse() {
    // a numeric target flips the whole pipeline to regression
    let path = std::env::temp_dir().join(format!("pmlp_realdata_reg_{}.csv", std::process::id()));
    let mut text = String::from("x1,x2,y\n");
    for i in 0..60 {
        let (a, b) = (i as f32 * 0.1, (i % 7) as f32 * 0.5);
        text.push_str(&format!("{a:.2},{b:.2},{:.3}\n", 2.0 * a - b + 0.5));
    }
    std::fs::write(&path, &text).unwrap();
    let cfg = ExperimentConfig {
        data_path: Some(path.to_str().unwrap().to_string()),
        target: Some("y".into()),
        hidden_sizes: vec![4],
        acts: vec![Act::Tanh],
        epochs: 5,
        warmup_epochs: 1,
        batch: 10,
        lr: 0.05,
        threads: 1,
        ..Default::default()
    };
    let trained = run_experiment_trained(&cfg).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(trained.config.loss, Loss::Mse);
    assert_eq!(trained.out_dim, 1);
    let pre = trained.preprocessor.as_ref().unwrap();
    assert_eq!(pre.n_classes(), None);
    assert!(trained.report.ranked[0].val_loss.is_finite());
}
