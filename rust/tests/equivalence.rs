//! The paper's central correctness claim, end-to-end across the 2×2
//! engine grid: fused training is EXACTLY independent per-model training.
//!
//! All four engines start from identical init (seeded per original model
//! index) and see identical batches; after several epochs the trained
//! parameters must agree within float tolerance.

use std::path::Path;

use parallel_mlps::coordinator::BatchSet;
use parallel_mlps::data;
use parallel_mlps::nn::init::{extract_model, init_pool};
use parallel_mlps::nn::loss::Loss;
use parallel_mlps::nn::mlp::MlpTrainer;
use parallel_mlps::nn::optimizer::OptimizerKind;
use parallel_mlps::nn::parallel::ParallelEngine;
use parallel_mlps::runtime::{PjrtParallelEngine, PjrtRuntime, PjrtSequentialEngine};
use parallel_mlps::util::rng::Rng;

const F: usize = 4;
const B: usize = 8;
const O: usize = 2;
const LR: f32 = 0.05;
const EPOCHS: usize = 3;
const SEED: u64 = 1234;

fn artifacts() -> Option<PjrtRuntime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match PjrtRuntime::new(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping pjrt tests: {e} (run `make artifacts`)");
            None
        }
    }
}

/// Train all four engines on the same workload; return per-engine fused
/// params flattened per model for comparison.
#[test]
fn four_way_engine_equivalence() {
    let Some(rt) = artifacts() else { return };
    let layout = rt.manifest.layout("smoke").expect("smoke pool");
    let spec = layout.spec().clone();
    let fused0 = init_pool(SEED, &layout, F, O);

    let mut rng = Rng::new(SEED);
    let ds = data::random_regression(B * 4, F, O, &mut rng);
    let batches = BatchSet::new(&ds, B, true).unwrap();

    // 1. native fused
    let mut native =
        ParallelEngine::new(layout.clone(), fused0.clone(), Loss::Mse, F, O, B, 2);
    // 2. pjrt fused (Pallas M3 artifact)
    let mut pjrt = PjrtParallelEngine::new(&rt, "smoke", F, B, Loss::Mse, &fused0).unwrap();
    // 3. pjrt sequential (per-model artifacts, exact activations)
    let mut pseq =
        PjrtSequentialEngine::new(&rt, &layout, F, B, O, Loss::Mse, &fused0, true).unwrap();
    // 4. native sequential
    let mut nseq: Vec<MlpTrainer> = (0..spec.n_models())
        .map(|m| {
            MlpTrainer::new(
                extract_model(&fused0, &layout, m),
                spec.models()[m].1,
                Loss::Mse,
                OptimizerKind::Sgd,
                1,
            )
        })
        .collect();

    for _ in 0..EPOCHS {
        for (x, y) in &batches.batches {
            native.step(x, y, LR);
            pjrt.step(x, y, LR).unwrap();
            pseq.step_all(x, y, LR).unwrap();
            for t in nseq.iter_mut() {
                t.step(x, y, LR);
            }
        }
    }

    let pjrt_fused = pjrt.params_fused().unwrap();
    let native_fused = native.params_fused();
    for m in 0..spec.n_models() {
        let h = spec.models()[m].0 as usize;
        let a = extract_model(&native_fused, &layout, m);
        let b_ = extract_model(&pjrt_fused, &layout, m);
        let c = pseq.extract(m, h).unwrap();
        let d = &nseq[m].params;
        let ab = a.max_abs_diff(&b_);
        let ac = a.max_abs_diff(&c);
        let ad = a.max_abs_diff(d);
        assert!(ab < 1e-4, "model {m}: native-fused vs pjrt-fused diff {ab}");
        assert!(ac < 1e-4, "model {m}: native-fused vs pjrt-seq diff {ac}");
        assert!(ad < 1e-4, "model {m}: native-fused vs native-seq diff {ad}");
    }
}

#[test]
fn pjrt_fused_ce_loss_matches_native() {
    let Some(rt) = artifacts() else { return };
    let layout = rt.manifest.layout("smoke").expect("smoke pool");
    let fused0 = init_pool(77, &layout, F, O);
    let mut rng = Rng::new(5150);
    let mut x = parallel_mlps::tensor::Tensor::zeros(&[B, F]);
    rng.fill_normal(x.data_mut(), 0.0, 1.0);
    let mut y = parallel_mlps::tensor::Tensor::zeros(&[B, O]);
    for bi in 0..B {
        y.set2(bi, rng.below(O), 1.0);
    }
    let mut native = ParallelEngine::new(layout.clone(), fused0.clone(), Loss::Ce, F, O, B, 2);
    let mut pjrt = PjrtParallelEngine::new(&rt, "smoke", F, B, Loss::Ce, &fused0).unwrap();
    for _ in 0..4 {
        let ln = native.step(&x, &y, 0.1);
        let lp = pjrt.step(&x, &y, 0.1).unwrap();
        for (a, b) in ln.iter().zip(&lp) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
}

#[test]
fn pjrt_eval_and_predict_consistent() {
    let Some(rt) = artifacts() else { return };
    let layout = rt.manifest.layout("smoke").expect("smoke pool");
    let fused0 = init_pool(31, &layout, F, O);
    let mut rng = Rng::new(6);
    let ds = data::random_regression(B, F, O, &mut rng);
    let (x, y) = ds.batch(0, B);

    let pjrt = PjrtParallelEngine::new(&rt, "smoke", F, B, Loss::Mse, &fused0).unwrap();
    let (pl, pm) = pjrt.evaluate(&x, &y).unwrap();
    let mut native = ParallelEngine::new(layout.clone(), fused0, Loss::Mse, F, O, B, 2);
    let (nl, nm) = native.evaluate(&x, &y);
    for i in 0..pl.len() {
        assert!((pl[i] - nl[i]).abs() < 1e-4);
        assert!((pm[i] - nm[i]).abs() < 1e-4);
    }

    // predict: per-slot outputs match native forward
    let yp = pjrt.predict(&x).unwrap();
    let yn = native.forward(&x);
    assert!(yp.max_abs_diff(&yn) < 1e-4);
}

#[test]
fn training_converges_on_learnable_task_via_pjrt() {
    // E2E sanity on the artifact path: losses decrease on a teacher task.
    let Some(rt) = artifacts() else { return };
    let layout = rt.manifest.layout("smoke").expect("smoke pool");
    let fused0 = init_pool(8, &layout, F, O);
    let mut rng = Rng::new(9);
    let ds = data::teacher_mlp(64, F, O, 3, &mut rng);
    let batches = BatchSet::new(&ds, B, true).unwrap();
    let mut pjrt = PjrtParallelEngine::new(&rt, "smoke", F, B, Loss::Mse, &fused0).unwrap();
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for epoch in 0..30 {
        let mut acc = 0.0;
        for (x, y) in &batches.batches {
            let lm = pjrt.step(x, y, 0.05).unwrap();
            acc = lm.iter().sum::<f32>() / lm.len() as f32;
        }
        if epoch == 0 {
            first = acc;
        }
        last = acc;
    }
    assert!(last < first * 0.5, "first={first} last={last}");
}
