//! Differential kernel harness: every non-oracle kernel against the
//! naive oracle over seeded random shapes (ragged M/K/N, zero-size
//! edges, mixed-depth stack layouts), across thread counts and tile
//! sizes.
//!
//! Two exactness tiers (see `rust/src/tensor/kernels/mod.rs`):
//!
//! * **Tier 1 — bit-exact** (`Naive`, `Blocked`): every output element
//!   is a single-accumulator sum over `k` in ascending order, no
//!   reassociation anywhere, so these tests assert **exact bit
//!   equality** across every shape, tile, and thread count.
//! * **Tier 2 — bounded-ulp** (`Simd`): FMA fuses multiply+add into one
//!   rounding and the NT-family kernels keep 8 interleaved partial sums
//!   per element, so bits may differ from the oracle. The bound used
//!   here: both kernels' forward error vs the exact sum is at most
//!   `~k·eps·S` where `S = Σ|aᵢ||bᵢ| (+|bias|)` is the cancellation-free
//!   magnitude of the reduction, so the two results differ by at most a
//!   small multiple of that — `assert_simd_close` computes `S` with the
//!   naive kernel on absolute-value operands and accepts
//!   `|simd − naive| ≤ 16·(k+2)·eps·S`, OR'd with a 64-ulp escape for
//!   tiny outputs. Non-finite results must classify identically
//!   (NaN↔NaN, same-signed ∞). Thread-count invariance stays **bit
//!   exact** even for `Simd` (threads partition output rows and never
//!   touch per-element math); tile sizes may legitimately move `Simd`
//!   low-order bits (k-slice boundaries move the horizontal
//!   reductions), which is exactly why the sweep runs every stress tile
//!   through the tolerance check.
//!
//! Thread counts: each dispatch is exercised at 1, 2 and 8 workers (the
//! explicit-argument equivalent of `PMLP_THREADS` ∈ {1, 2, 8}; CI
//! additionally runs the whole suite under the env-var matrix).
//!
//! On hosts without AVX2+FMA the `Simd` dispatch delegates to
//! `Blocked`, so the tier-2 tests still run everywhere — they just
//! degenerate into (already covered) bit-equality.

use parallel_mlps::nn::act::ALL_ACTS;
use parallel_mlps::nn::stack::{LayerStack, StackModel};
use parallel_mlps::tensor::kernels::{
    self, BlockDiag, Kernel, KernelConfig, Tile, NR, TILE_CANDIDATES,
};
use parallel_mlps::tensor::Tensor;
use parallel_mlps::util::rng::Rng;

const THREADS: [usize; 3] = [1, 2, 8];

fn cfg(kernel: Kernel, tile: Tile) -> KernelConfig {
    KernelConfig { kernel, tile }
}

fn naive() -> KernelConfig {
    KernelConfig::naive()
}

/// Tiles chosen to force every path: micro-tiles only, heavy edge
/// remainders, single giant block, and the shipped default.
fn stress_tiles() -> [Tile; 4] {
    [Tile { nc: NR, kc: 4 }, Tile { nc: 24, kc: 7 }, Tile { nc: 4096, kc: 4096 }, Tile::DEFAULT]
}

fn rand_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; len];
    rng.fill_normal(&mut v, 0.0, 1.0);
    v
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The shape sweep: handpicked edges (zero-size dims, micro-tile
/// boundaries, single elements) plus seeded random ragged shapes.
fn shape_sweep(rng: &mut Rng) -> Vec<(usize, usize, usize)> {
    let mut shapes = vec![
        (0, 3, 4),
        (3, 0, 4),
        (3, 4, 0),
        (0, 0, 0),
        (1, 1, 1),
        (4, 8, 8),   // exactly one 4x8 tile
        (5, 9, 9),   // one tile + every edge kind
        (3, 5, 7),   // all-edge (below MR/NR)
        (17, 31, 23),
        (64, 10, 64),
        (32, 10, 160), // the fused fwd shape class [B,F]x[F,H]
        (12, 130, 40), // k crosses several KC blocks
    ];
    for _ in 0..12 {
        shapes.push((rng.below(40), rng.below(40), rng.below(70)));
    }
    shapes
}

type RawKernel = fn(
    KernelConfig,
    &[f32],
    &[f32],
    &mut [f32],
    usize,
    usize,
    usize,
    usize,
) -> Result<(), kernels::ShapeError>;

fn ops() -> [(&'static str, RawKernel); 3] {
    [
        ("nt", kernels::matmul_nt_with as RawKernel),
        ("nn", kernels::matmul_nn_with as RawKernel),
        ("tn", kernels::matmul_tn_with as RawKernel),
    ]
}

/// Operand lengths for (m, k, n) per op.
fn operand_lens(op: &str, m: usize, k: usize, n: usize) -> (usize, usize) {
    match op {
        "nt" => (m * k, n * k),
        "nn" => (m * k, k * n),
        "tn" => (k * m, k * n),
        _ => unreachable!(),
    }
}

#[test]
fn blocked_bit_equals_naive_across_shapes_threads_and_tiles() {
    let mut rng = Rng::new(0x5EED);
    let shapes = shape_sweep(&mut rng);
    for (op_name, op) in ops() {
        for &(m, k, n) in &shapes {
            let (la, lb) = operand_lens(op_name, m, k, n);
            let a = rand_vec(&mut rng, la);
            let b = rand_vec(&mut rng, lb);
            let mut want = vec![f32::NAN; m * n]; // NaN canary: must be overwritten
            op(naive(), &a, &b, &mut want, m, k, n, 1).unwrap();
            for &threads in &THREADS {
                let mut again = vec![f32::NAN; m * n];
                op(naive(), &a, &b, &mut again, m, k, n, threads).unwrap();
                assert_eq!(
                    bits(&again),
                    bits(&want),
                    "{op_name} naive {m}x{k}x{n}: thread count changed bits (t={threads})"
                );
                for tile in stress_tiles() {
                    let mut got = vec![f32::NAN; m * n];
                    op(cfg(Kernel::Blocked, tile), &a, &b, &mut got, m, k, n, threads).unwrap();
                    assert_eq!(
                        bits(&got),
                        bits(&want),
                        "{op_name} {m}x{k}x{n}: blocked != naive (t={threads}, tile={tile:?})"
                    );
                }
            }
        }
    }
}

#[test]
fn nonfinite_values_propagate_identically() {
    // zero-skips or reordering would make NaN/∞ propagation diverge
    // between kernels; neither kernel may take such shortcuts
    let (m, k, n) = (6, 9, 17);
    let mut rng = Rng::new(0xF1F1);
    for (op_name, op) in ops() {
        let (la, lb) = operand_lens(op_name, m, k, n);
        let mut a = rand_vec(&mut rng, la);
        let mut b = rand_vec(&mut rng, lb);
        a[3] = f32::NAN;
        a[7] = 0.0;
        b[5] = f32::INFINITY;
        b[11] = 0.0;
        let mut want = vec![0.0f32; m * n];
        op(naive(), &a, &b, &mut want, m, k, n, 1).unwrap();
        assert!(want.iter().any(|v| !v.is_finite()), "{op_name}: canary never propagated");
        for &threads in &THREADS {
            let mut got = vec![0.0f32; m * n];
            op(KernelConfig::blocked(), &a, &b, &mut got, m, k, n, threads).unwrap();
            assert_eq!(bits(&got), bits(&want), "{op_name} t={threads}");
        }
    }
}

#[test]
fn autotuned_tile_is_a_pure_performance_knob() {
    // whatever the probe picks must produce the same bits as every
    // candidate it rejected
    let mut rng = Rng::new(0x7117);
    let (m, k, n) = (23, 37, 95);
    let a = rand_vec(&mut rng, m * k);
    let b = rand_vec(&mut rng, n * k);
    let mut want = vec![0.0f32; m * n];
    kernels::matmul_nt_with(naive(), &a, &b, &mut want, m, k, n, 1).unwrap();
    let picked = kernels::autotune_tile();
    assert!(TILE_CANDIDATES.contains(&picked));
    for tile in TILE_CANDIDATES {
        let mut got = vec![0.0f32; m * n];
        kernels::matmul_nt_with(cfg(Kernel::Blocked, tile), &a, &b, &mut got, m, k, n, 2)
            .unwrap();
        assert_eq!(bits(&got), bits(&want), "tile {tile:?}");
    }
}

// ---------------------------------------------------------------------------
// Block-diagonal kernel: random mixed-depth stack layouts
// ---------------------------------------------------------------------------

fn random_stack(rng: &mut Rng) -> (LayerStack, usize, usize) {
    let n_models = 1 + rng.below(6);
    let features = 1 + rng.below(6);
    let out = 1 + rng.below(3);
    let models: Vec<StackModel> = (0..n_models)
        .map(|_| {
            let depth = 1 + rng.below(3);
            StackModel {
                hidden: (0..depth).map(|_| 1 + rng.below(9) as u32).collect(),
                act: ALL_ACTS[rng.below(10)],
            }
        })
        .collect();
    (LayerStack::new(models, features, out).unwrap(), features, out)
}

#[test]
fn stack_forward_blocked_matches_naive_and_dense_extraction_bitwise() {
    let mut rng = Rng::new(0xB10C);
    for trial in 0..8 {
        let (stack, features, _) = random_stack(&mut rng);
        let p = stack.init(rng.next_u64());
        let b = 1 + rng.below(12);
        let mut x = Tensor::zeros(&[b, features]);
        rng.fill_normal(x.data_mut(), 0.0, 1.0);

        let want = stack.forward_with(naive(), &p, &x, 1);
        for &threads in &THREADS {
            for kernel in [Kernel::Naive, Kernel::Blocked] {
                let got = stack.forward_with(cfg(kernel, Tile::DEFAULT), &p, &x, threads);
                assert_eq!(
                    bits(got.data()),
                    bits(want.data()),
                    "trial {trial}: {kernel:?} t={threads} diverged from the oracle"
                );
            }
        }
        // per-model dense extraction runs the same in-order math, so the
        // fused pool and the standalone winner agree at the bit level
        for m in 0..stack.n_models() {
            let dense = stack.extract(&p, m);
            let standalone = dense.forward_with(naive(), &x, 1);
            let fused = stack.model_logits(&want, m);
            assert_eq!(
                bits(standalone.data()),
                bits(fused.data()),
                "trial {trial} model {m}: dense twin != fused span"
            );
        }
    }
}

#[test]
fn block_diag_direct_dispatch_matches_naive() {
    // drive the raw block-diagonal entry point (identity gaps included)
    // without going through LayerStack
    let mut rng = Rng::new(0xD1A6);
    let spans_in = [(0usize, 3usize), (3, 7), (7, 8)];
    let spans_out = [(0usize, 9usize), (9, 13), (13, 16)];
    // model 1 is an identity gap: its output span must stay untouched
    let offs = [Some(0usize), None, Some(9 * 3)];
    let (w_in, w_out, rows) = (8usize, 16usize, 11usize);
    let w = rand_vec(&mut rng, 9 * 3 + 3 * 1);
    let bias = rand_vec(&mut rng, w_out);
    let input = rand_vec(&mut rng, rows * w_in);
    let bd = BlockDiag { spans_in: &spans_in, spans_out: &spans_out, offs: &offs };

    let canary = 123.456f32;
    let mut want = vec![canary; rows * w_out];
    kernels::block_diag_with(naive(), &input, &w, &bias, &mut want, rows, w_in, w_out, &bd, 1)
        .unwrap();
    // identity span untouched
    for r in 0..rows {
        for c in 9..13 {
            assert_eq!(want[r * w_out + c], canary, "identity span written at ({r},{c})");
        }
    }
    for &threads in &THREADS {
        for tile in stress_tiles() {
            let mut got = vec![canary; rows * w_out];
            kernels::block_diag_with(
                cfg(Kernel::Blocked, tile),
                &input,
                &w,
                &bias,
                &mut got,
                rows,
                w_in,
                w_out,
                &bd,
                threads,
            )
            .unwrap();
            assert_eq!(bits(&got), bits(&want), "t={threads} tile={tile:?}");
        }
    }
}

// ---------------------------------------------------------------------------
// Tier 2: the simd kernel against the oracle, bounded-ulp
// ---------------------------------------------------------------------------

/// Relative-bound constant: both kernels carry `≲ k·eps·S` forward
/// error, so 16× the combined bound leaves slack without letting real
/// bugs (wrong element, dropped k-slice) through — those miss by orders
/// of magnitude, not ulps.
const SIMD_REL_C: f32 = 16.0;
/// Ulp escape hatch for outputs whose magnitude-oracle `S` underflows
/// the relative bound (heavy cancellation near zero).
const SIMD_MAX_ULPS: i64 = 64;

/// Map a float to a lexicographically ordered integer so ulp distance
/// is a subtraction (±0.0 both map to 0).
fn ulp_key(x: f32) -> i64 {
    let b = x.to_bits();
    if b & 0x8000_0000 != 0 {
        -((b & 0x7fff_ffff) as i64)
    } else {
        b as i64
    }
}

/// Tier-2 comparison: `got` (simd) vs `want` (naive oracle), with
/// `scale[i] = S` from the absolute-value magnitude oracle and `k` the
/// reduction length. Bit-equal elements pass unconditionally, so
/// untouched canary spans and the no-AVX2 delegation path are covered
/// for free.
fn assert_simd_close(got: &[f32], want: &[f32], scale: &[f32], k: usize, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length mismatch");
    assert_eq!(got.len(), scale.len(), "{ctx}: scale oracle length mismatch");
    for (i, ((&g, &w), &s)) in got.iter().zip(want).zip(scale).enumerate() {
        if g.to_bits() == w.to_bits() {
            continue;
        }
        if w.is_nan() {
            assert!(g.is_nan(), "{ctx}[{i}]: oracle NaN, simd {g}");
            continue;
        }
        if w.is_infinite() {
            assert_eq!(g, w, "{ctx}[{i}]: oracle {w}, simd {g}");
            continue;
        }
        assert!(g.is_finite(), "{ctx}[{i}]: oracle finite {w}, simd {g}");
        let tol = SIMD_REL_C * (k as f32 + 2.0) * f32::EPSILON * s;
        let diff = (g - w).abs();
        let ulps = (ulp_key(g) - ulp_key(w)).abs();
        assert!(
            diff <= tol || ulps <= SIMD_MAX_ULPS,
            "{ctx}[{i}]: |{g} - {w}| = {diff:e} exceeds tol {tol:e} \
             ({ulps} ulps, scale {s:e}, k={k})"
        );
    }
}

#[test]
fn simd_matches_naive_within_bound_across_shapes_threads_and_tiles() {
    let mut rng = Rng::new(0x51AD);
    let shapes = shape_sweep(&mut rng);
    for (op_name, op) in ops() {
        for &(m, k, n) in &shapes {
            let (la, lb) = operand_lens(op_name, m, k, n);
            let a = rand_vec(&mut rng, la);
            let b = rand_vec(&mut rng, lb);
            let abs_a: Vec<f32> = a.iter().map(|v| v.abs()).collect();
            let abs_b: Vec<f32> = b.iter().map(|v| v.abs()).collect();
            let mut want = vec![f32::NAN; m * n];
            op(naive(), &a, &b, &mut want, m, k, n, 1).unwrap();
            let mut scale = vec![0.0f32; m * n];
            op(naive(), &abs_a, &abs_b, &mut scale, m, k, n, 1).unwrap();
            for &threads in &THREADS {
                for tile in stress_tiles() {
                    let mut got = vec![f32::NAN; m * n];
                    op(cfg(Kernel::Simd, tile), &a, &b, &mut got, m, k, n, threads).unwrap();
                    assert_simd_close(
                        &got,
                        &want,
                        &scale,
                        k,
                        &format!("{op_name} {m}x{k}x{n} t={threads} tile={tile:?}"),
                    );
                }
            }
        }
    }
}

#[test]
fn simd_is_thread_count_invariant_bitwise() {
    // Row partitioning never touches per-element math, so even the
    // reassociating kernel must be bit-stable across thread counts.
    let mut rng = Rng::new(0x51D7);
    for (op_name, op) in ops() {
        // The small shapes clamp parallel_chunks to chunk == MR (trivially
        // aligned); (80, …) and (160, …) are the regression shapes where
        // len/(threads·4) exceeds MR and is NOT naturally a multiple of it
        // (80 → 10 at 2 threads, 160 → 5 at 8 threads), so they fail unless
        // parallel_chunks rounds its chunk size up to an MR multiple.
        for &(m, k, n) in &[
            (5usize, 9usize, 9usize),
            (17, 31, 23),
            (32, 10, 160),
            (80, 17, 9),
            (160, 33, 20),
        ] {
            let (la, lb) = operand_lens(op_name, m, k, n);
            let a = rand_vec(&mut rng, la);
            let b = rand_vec(&mut rng, lb);
            let mut want = vec![0.0f32; m * n];
            op(KernelConfig::simd(), &a, &b, &mut want, m, k, n, 1).unwrap();
            for &threads in &THREADS[1..] {
                let mut got = vec![0.0f32; m * n];
                op(KernelConfig::simd(), &a, &b, &mut got, m, k, n, threads).unwrap();
                assert_eq!(
                    bits(&got),
                    bits(&want),
                    "{op_name} {m}x{k}x{n}: simd thread count changed bits (t={threads})"
                );
            }
        }
    }
}

#[test]
fn simd_nonfinite_values_classify_identically() {
    // Same canary layout as the tier-1 test: NaN/∞ must land in the
    // same output positions (FMA may change NaN payloads, never
    // placement — the simd kernels take no zero-skip shortcuts either).
    let (m, k, n) = (6, 9, 17);
    let mut rng = Rng::new(0xF1F2);
    for (op_name, op) in ops() {
        let (la, lb) = operand_lens(op_name, m, k, n);
        let mut a = rand_vec(&mut rng, la);
        let mut b = rand_vec(&mut rng, lb);
        a[3] = f32::NAN;
        a[7] = 0.0;
        b[5] = f32::INFINITY;
        b[11] = 0.0;
        let mut want = vec![0.0f32; m * n];
        op(naive(), &a, &b, &mut want, m, k, n, 1).unwrap();
        assert!(want.iter().any(|v| !v.is_finite()), "{op_name}: canary never propagated");
        for &threads in &THREADS {
            let mut got = vec![0.0f32; m * n];
            op(KernelConfig::simd(), &a, &b, &mut got, m, k, n, threads).unwrap();
            for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    g.is_finite(),
                    w.is_finite(),
                    "{op_name}[{i}] t={threads}: finiteness diverged ({g} vs {w})"
                );
                if w.is_nan() {
                    assert!(g.is_nan(), "{op_name}[{i}] t={threads}: {g} vs NaN");
                }
            }
        }
    }
}

#[test]
fn simd_block_diag_matches_naive_within_bound() {
    // Same geometry as the tier-1 block-diag test, including the
    // identity gap (whose canary must survive the simd path untouched).
    let mut rng = Rng::new(0xD1A7);
    let spans_in = [(0usize, 3usize), (3, 7), (7, 8)];
    let spans_out = [(0usize, 9usize), (9, 13), (13, 16)];
    let offs = [Some(0usize), None, Some(9 * 3)];
    let (w_in, w_out, rows) = (8usize, 16usize, 11usize);
    let w = rand_vec(&mut rng, 9 * 3 + 3 * 1);
    let bias = rand_vec(&mut rng, w_out);
    let input = rand_vec(&mut rng, rows * w_in);
    let abs_w: Vec<f32> = w.iter().map(|v| v.abs()).collect();
    let abs_bias: Vec<f32> = bias.iter().map(|v| v.abs()).collect();
    let abs_input: Vec<f32> = input.iter().map(|v| v.abs()).collect();
    let bd = BlockDiag { spans_in: &spans_in, spans_out: &spans_out, offs: &offs };

    let canary = 123.456f32;
    let mut want = vec![canary; rows * w_out];
    kernels::block_diag_with(naive(), &input, &w, &bias, &mut want, rows, w_in, w_out, &bd, 1)
        .unwrap();
    let mut scale = vec![canary; rows * w_out];
    kernels::block_diag_with(
        naive(),
        &abs_input,
        &abs_w,
        &abs_bias,
        &mut scale,
        rows,
        w_in,
        w_out,
        &bd,
        1,
    )
    .unwrap();
    // widest per-model fan-in bounds every element's reduction length
    let k_max = spans_in.iter().map(|&(s, e)| e - s).max().unwrap();
    for &threads in &THREADS {
        for tile in stress_tiles() {
            let mut got = vec![canary; rows * w_out];
            kernels::block_diag_with(
                cfg(Kernel::Simd, tile),
                &input,
                &w,
                &bias,
                &mut got,
                rows,
                w_in,
                w_out,
                &bd,
                threads,
            )
            .unwrap();
            for r in 0..rows {
                for c in 9..13 {
                    assert_eq!(
                        got[r * w_out + c].to_bits(),
                        canary.to_bits(),
                        "identity span written at ({r},{c})"
                    );
                }
            }
            assert_simd_close(
                &got,
                &want,
                &scale,
                k_max,
                &format!("block_diag t={threads} tile={tile:?}"),
            );
        }
    }
}

#[test]
fn simd_stack_forward_stays_close_to_naive() {
    // End-to-end through LayerStack: activations between layers compound
    // the per-matmul drift, so this uses a looser (still tiny) relative
    // bound rather than the per-reduction magnitude oracle.
    let mut rng = Rng::new(0xB10D);
    for trial in 0..8 {
        let (stack, features, _) = random_stack(&mut rng);
        let p = stack.init(rng.next_u64());
        let b = 1 + rng.below(12);
        let mut x = Tensor::zeros(&[b, features]);
        rng.fill_normal(x.data_mut(), 0.0, 1.0);

        let want = stack.forward_with(naive(), &p, &x, 1);
        for &threads in &THREADS {
            let got = stack.forward_with(KernelConfig::simd(), &p, &x, threads);
            for (i, (&g, &w)) in got.data().iter().zip(want.data()).enumerate() {
                let tol = 1e-3 * (1.0 + w.abs());
                assert!(
                    (g - w).abs() <= tol,
                    "trial {trial}[{i}] t={threads}: simd stack drifted: {g} vs {w}"
                );
            }
        }
    }
}

#[test]
fn simd_dispatch_reports_the_same_typed_errors() {
    // Shape validation happens before kernel selection; the simd arm
    // must not bypass it.
    let (m, k, n) = (2usize, 3usize, 2usize);
    for (op_name, op) in ops() {
        let (la, lb) = operand_lens(op_name, m, k, n);
        let good_b = vec![0.0f32; lb];
        let mut good_c = vec![0.0f32; m * n];
        let bad_a = vec![0.0f32; la + 1];
        let e = op(KernelConfig::simd(), &bad_a, &good_b, &mut good_c, m, k, n, 1).unwrap_err();
        assert_eq!(e.op(), format!("matmul_{op_name}"), "{e}");
    }
}

// ---------------------------------------------------------------------------
// Typed shape errors
// ---------------------------------------------------------------------------

#[test]
fn every_matmul_op_reports_typed_mismatches() {
    for (op_name, op) in ops() {
        let (m, k, n) = (2usize, 3usize, 2usize);
        let (la, lb) = operand_lens(op_name, m, k, n);
        let good_a = vec![0.0f32; la];
        let good_b = vec![0.0f32; lb];
        let mut good_c = vec![0.0f32; m * n];
        op(naive(), &good_a, &good_b, &mut good_c, m, k, n, 1).unwrap();

        for kernel in [Kernel::Naive, Kernel::Blocked] {
            let c = cfg(kernel, Tile::DEFAULT);
            let bad_a = vec![0.0f32; la + 1];
            let e = op(c, &bad_a, &good_b, &mut good_c, m, k, n, 1).unwrap_err();
            assert_eq!(e.op(), format!("matmul_{op_name}"), "{e}");
            let bad_b = vec![0.0f32; lb + 2];
            let e = op(c, &good_a, &bad_b, &mut good_c, m, k, n, 1).unwrap_err();
            assert!(e.to_string().contains("shape mismatch"), "{e}");
            let mut bad_c = vec![0.0f32; m * n - 1];
            let e = op(c, &good_a, &good_b, &mut bad_c, m, k, n, 1).unwrap_err();
            assert!(e.to_string().contains('C'), "{e}");
        }
    }
}

#[test]
fn overflowing_extents_are_rejected_not_wrapped() {
    // a wrapped rows*cols would validate empty slices against absurd
    // dims and hand the unsafe kernels out-of-bounds extents
    let mut c: Vec<f32> = vec![];
    let e = kernels::matmul_nt_with(naive(), &[], &[0.0; 32], &mut c, 1 << 62, 4, 8, 1)
        .unwrap_err();
    assert!(e.to_string().contains("overflow"), "{e}");
    let e = kernels::matmul_nn_with(naive(), &[], &[], &mut c, 1 << 62, 4, usize::MAX, 1)
        .unwrap_err();
    assert!(e.to_string().contains("overflow"), "{e}");
    let e = kernels::matmul_tn_with(naive(), &[], &[], &mut c, usize::MAX, 2, usize::MAX, 1)
        .unwrap_err();
    assert!(e.to_string().contains("overflow"), "{e}");
}

#[test]
fn block_diag_rejects_bad_geometry() {
    let spans_in = [(0usize, 2usize)];
    let spans_out = [(0usize, 3usize)];
    let offs = [Some(0usize)];
    let w = vec![0.0f32; 6];
    let bias = vec![0.0f32; 3];
    let input = vec![0.0f32; 4];
    let mut out = vec![0.0f32; 6];
    let ok = BlockDiag { spans_in: &spans_in, spans_out: &spans_out, offs: &offs };
    kernels::block_diag_with(naive(), &input, &w, &bias, &mut out, 2, 2, 3, &ok, 1).unwrap();

    // span table length mismatch
    let bad = BlockDiag { spans_in: &spans_in, spans_out: &[], offs: &offs };
    let e = kernels::block_diag_with(naive(), &input, &w, &bias, &mut out, 2, 2, 3, &bad, 1)
        .unwrap_err();
    assert!(e.to_string().contains("span tables"), "{e}");

    // span out of bounds
    let oob = [(0usize, 9usize)];
    let bad = BlockDiag { spans_in: &oob, spans_out: &spans_out, offs: &offs };
    assert!(kernels::block_diag_with(naive(), &input, &w, &bias, &mut out, 2, 2, 3, &bad, 1)
        .is_err());

    // packed block runs past the weight buffer
    let far = [Some(3usize)];
    let bad = BlockDiag { spans_in: &spans_in, spans_out: &spans_out, offs: &far };
    let e = kernels::block_diag_with(naive(), &input, &w, &bias, &mut out, 2, 2, 3, &bad, 1)
        .unwrap_err();
    assert!(e.to_string().contains("packed"), "{e}");

    // bias width mismatch
    let e = kernels::block_diag_with(naive(), &input, &w, &bias[..2], &mut out, 2, 2, 3, &ok, 1)
        .unwrap_err();
    assert!(e.to_string().contains("bias"), "{e}");
}
