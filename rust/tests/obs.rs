//! Observability end-to-end: a traced train → halving → checkpoint →
//! serve session must produce a JSONL trace where every line parses,
//! every span balances, and the per-kind histograms carry real data.
//!
//! The trace sink is process-global state, so every test that touches it
//! serializes on [`LOCK`] and runs against a fresh capture generation.

use std::sync::Mutex;

use parallel_mlps::coordinator::{BatchSet, TrainSession};
use parallel_mlps::data;
use parallel_mlps::io::{PoolCheckpoint, RankEntry};
use parallel_mlps::nn::act::Act;
use parallel_mlps::nn::init::init_pool;
use parallel_mlps::nn::loss::Loss;
use parallel_mlps::nn::parallel::ParallelEngine;
use parallel_mlps::obs::summary::{render, summarize};
use parallel_mlps::obs::trace;
use parallel_mlps::pool::{PoolLayout, PoolSpec};
use parallel_mlps::selection::{halving_run, HalvingArm, HalvingConfig};
use parallel_mlps::serve::bench::synthetic_model;
use parallel_mlps::serve::{ServeConfig, Server};
use parallel_mlps::util::rng::Rng;

const F: usize = 4;
const O: usize = 2;
const B: usize = 8;
const SEED: u64 = 41;

/// The sink is one-per-process; tests must not interleave generations.
static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn capture_to_string(buf: &Mutex<Vec<u8>>) -> String {
    String::from_utf8(buf.lock().unwrap().clone()).expect("trace must be UTF-8")
}

#[test]
fn traced_session_produces_balanced_parseable_trace() {
    let _guard = lock();
    let cap = trace::init_capture();
    assert!(trace::enabled());

    // train: 3 epochs over a small fused pool (spans on this thread)
    let spec = PoolSpec::from_grid(&[2, 4], &[Act::Relu, Act::Tanh], 1).unwrap();
    let layout = PoolLayout::build(&spec);
    let fused = init_pool(SEED, &layout, F, O);
    let mut engine =
        ParallelEngine::new(layout.clone(), fused.clone(), Loss::Mse, F, O, B, 1);
    let mut rng = Rng::new(SEED);
    let ds = data::random_regression(B * 4, F, O, &mut rng);
    let batches = BatchSet::new(&ds, B, false).unwrap();
    TrainSession::builder().epochs(3).lr(0.05).run_with_batches(&mut engine, &batches).unwrap();

    // successive halving over the same pool shape (halving.rung spans)
    let hcfg = HalvingConfig { eta: 2, rung_epochs: 1 };
    let val = data::random_regression(B * 2, F, O, &mut rng);
    let arm = HalvingArm {
        engine: ParallelEngine::new(layout.clone(), fused, Loss::Mse, F, O, B, 1),
        train: ds.clone(),
        val,
    };
    halving_run(vec![arm], B, 0.05, Loss::Mse, &hcfg, false).unwrap();

    // checkpoint save + load (io.checkpoint spans)
    let ckpt = PoolCheckpoint::from_shallow(
        &layout,
        F,
        O,
        Loss::Mse,
        &engine.params_fused(),
        vec![RankEntry { index: 0, val_loss: 0.5, val_metric: 0.5 }],
    )
    .unwrap();
    let path =
        std::env::temp_dir().join(format!("pmlp_obs_trace_{}.ckpt", std::process::id()));
    ckpt.save(&path).unwrap();
    PoolCheckpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    // serve a few rows (serve.batch spans, flushed when workers join)
    let model = synthetic_model(16, 8, 3, 9);
    let server =
        Server::start(model, ServeConfig { max_batch: 4, queue_cap: 64, threads: 1 }).unwrap();
    let client = server.client();
    for _ in 0..12 {
        let row: Vec<f32> = (0..8).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        client.predict(&row).unwrap();
    }
    server.shutdown();

    trace::flush();
    let text = capture_to_string(&cap);
    trace::disable();

    // every line is standalone JSON with an event type
    for (i, line) in text.lines().filter(|l| !l.trim().is_empty()).enumerate() {
        let v = parallel_mlps::util::json::parse(line)
            .unwrap_or_else(|e| panic!("line {} is not JSON: {e}\n{line}", i + 1));
        assert!(v.req("ev").unwrap().as_str().is_some(), "line {} lacks ev", i + 1);
    }

    // strict fold: unparseable lines or unbalanced spans are errors
    let sum = summarize(&text).expect("trace must summarize cleanly");
    assert!(sum.lines > 0);

    let epochs = sum.spans.get("train.epoch").expect("train.epoch spans");
    // 3 session epochs + the halving rungs' training epochs
    assert!(epochs.count >= 3, "epoch spans: {}", epochs.count);
    assert!(!epochs.hist.is_empty());
    assert!(epochs.hist.quantile(0.5) <= epochs.hist.quantile(0.99));

    let batches_stat = sum.spans.get("serve.batch").expect("serve.batch spans");
    assert!(batches_stat.count >= 1);
    assert!(batches_stat.hist.quantile(0.5) <= batches_stat.hist.quantile(0.99));

    assert!(sum.spans.get("halving.rung").map(|s| s.count).unwrap_or(0) >= 1);
    assert_eq!(sum.spans.get("io.checkpoint").map(|s| s.count), Some(2));

    let rows = sum.counters.get("train.rows").expect("train.rows counter");
    assert!(rows.sum > 0.0);

    // the CLI rendering of the same summary names both hot span kinds
    let rendered = render(&sum);
    assert!(rendered.contains("train.epoch"), "{rendered}");
    assert!(rendered.contains("serve.batch"), "{rendered}");
}

#[test]
fn disabled_sink_is_inert_and_captures_nothing() {
    let _guard = lock();
    trace::disable();
    assert!(!trace::enabled());

    // all entry points must be harmless no-ops when off
    let mut sp = trace::span("train.epoch");
    sp.field("epoch", 1usize);
    sp.end();
    trace::counter("train.rows", 128.0);
    trace::gauge("peak_rss_bytes", 1.0);
    trace::flush();

    // a fresh capture sees nothing from before its generation
    let cap = trace::init_capture();
    trace::flush();
    let before = capture_to_string(&cap);
    assert!(before.is_empty(), "stale events leaked: {before}");
    trace::disable();

    // and nothing emitted after disable reaches the dead capture either
    trace::counter("train.rows", 1.0);
    trace::flush();
    assert!(capture_to_string(&cap).is_empty());
}

#[test]
fn span_fields_survive_into_end_events() {
    let _guard = lock();
    let cap = trace::init_capture();
    let mut sp = trace::span("halving.rung");
    sp.field("rung", 2usize);
    sp.field("entering", 9usize);
    sp.end();
    trace::flush();
    let text = capture_to_string(&cap);
    trace::disable();

    let end_line = text
        .lines()
        .find(|l| l.contains("\"ev\": \"end\"") || l.contains("\"ev\":\"end\""))
        .expect("an end event");
    let v = parallel_mlps::util::json::parse(end_line).unwrap();
    assert_eq!(v.req("span").unwrap().as_str(), Some("halving.rung"));
    assert_eq!(v.req("rung").unwrap().as_usize(), Some(2));
    assert_eq!(v.req("entering").unwrap().as_usize(), Some(9));
    assert!(v.req("dur_us").unwrap().as_f64().is_some());
    let sum = summarize(&text).unwrap();
    assert_eq!(sum.spans.get("halving.rung").map(|s| s.count), Some(1));
}
