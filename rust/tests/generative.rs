//! Generative (property-style) integration tests: many random pools,
//! datasets and hyper-parameters; for each, the fused native engine must
//! reproduce per-model sequential training exactly — the paper's
//! independence claim swept across the configuration space.

use parallel_mlps::coordinator::BatchSet;
use parallel_mlps::data;
use parallel_mlps::nn::act::{Act, ALL_ACTS};
use parallel_mlps::nn::init::{extract_model, init_pool};
use parallel_mlps::nn::loss::Loss;
use parallel_mlps::nn::mlp::MlpTrainer;
use parallel_mlps::nn::optimizer::OptimizerKind;
use parallel_mlps::nn::parallel::ParallelEngine;
use parallel_mlps::pool::{PoolLayout, PoolSpec};
use parallel_mlps::util::rng::Rng;

fn random_pool(rng: &mut Rng) -> PoolSpec {
    let n = 1 + rng.below(10);
    let models: Vec<(u32, Act)> = (0..n)
        .map(|_| (1 + rng.below(9) as u32, ALL_ACTS[rng.below(10)]))
        .collect();
    PoolSpec::new(models).unwrap()
}

#[test]
fn fused_equals_sequential_across_random_configs() {
    let mut meta = Rng::new(0xF00D);
    for trial in 0..12 {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        let spec = random_pool(&mut rng);
        let f = 2 + rng.below(6);
        let o = 1 + rng.below(3);
        let b = [4usize, 8, 16][rng.below(3)];
        let n = b * (2 + rng.below(3));
        let lr = [0.01f32, 0.05, 0.1][rng.below(3)];
        let loss = if rng.below(2) == 0 { Loss::Mse } else { Loss::Ce };
        let epochs = 1 + rng.below(3);

        let layout = PoolLayout::build(&spec);
        let fused0 = init_pool(seed, &layout, f, o);
        let ds = if loss == Loss::Ce {
            data::blobs(n, f, o.max(2), &mut rng)
        } else {
            data::random_regression(n, f, o, &mut rng)
        };
        // CE blobs force out >= 2
        let o = ds.out_dim();
        let fused0 = if fused0.w2.shape()[0] != o { init_pool(seed, &layout, f, o) } else { fused0 };
        let batches = BatchSet::new(&ds, b, true).unwrap();

        let mut engine =
            ParallelEngine::new(layout.clone(), fused0.clone(), loss, f, o, b, 2);
        for _ in 0..epochs {
            for (x, y) in &batches.batches {
                engine.step(x, y, lr);
            }
        }
        let trained = engine.params_fused();

        for m in 0..spec.n_models() {
            let mut seq = MlpTrainer::new(
                extract_model(&fused0, &layout, m),
                spec.models()[m].1,
                loss,
                OptimizerKind::Sgd,
                1,
            );
            for _ in 0..epochs {
                for (x, y) in &batches.batches {
                    seq.step(x, y, lr);
                }
            }
            let got = extract_model(&trained, &layout, m);
            let diff = got.max_abs_diff(&seq.params);
            assert!(
                diff < 5e-4,
                "trial {trial} (seed {seed:#x}): model {m} of {:?} diverged by {diff} \
                 (f={f} o={o} b={b} lr={lr} loss={loss:?} epochs={epochs})",
                spec.models()[m]
            );
        }
    }
}

#[test]
fn random_layout_knobs_do_not_change_training() {
    // explicit (W, G) choices are a pure performance knob: results match
    let mut meta = Rng::new(0xBEEF);
    for _ in 0..6 {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        let spec = random_pool(&mut rng);
        let max_h = spec.max_hidden() as usize;
        let w = max_h.max(4 + rng.below(24)).div_ceil(4) * 4;
        let g = 1 + rng.below(8);
        let (f, o, b) = (4usize, 2usize, 8usize);
        let ds = data::random_regression(16, f, o, &mut rng);
        let batches = BatchSet::new(&ds, b, true).unwrap();

        let run = |layout: PoolLayout| {
            let fused0 = init_pool(seed, &layout, f, o);
            let mut e = ParallelEngine::new(layout.clone(), fused0, Loss::Mse, f, o, b, 1);
            for (x, y) in &batches.batches {
                e.step(x, y, 0.05);
            }
            (0..layout.n_models())
                .map(|m| extract_model(&e.params_fused(), &layout, m))
                .collect::<Vec<_>>()
        };
        let a = run(PoolLayout::build(&spec));
        let b_ = run(PoolLayout::build_with(&spec, w, g));
        for (m, (pa, pb)) in a.iter().zip(&b_).enumerate() {
            let diff = pa.max_abs_diff(pb);
            assert!(diff < 1e-5, "seed {seed:#x} model {m}: layout knobs changed results ({diff})");
        }
    }
}

#[test]
fn evaluation_is_pure() {
    // evaluate() must not mutate parameters
    let mut rng = Rng::new(77);
    let spec = random_pool(&mut rng);
    let layout = PoolLayout::build(&spec);
    let fused0 = init_pool(1, &layout, 4, 2);
    let mut e = ParallelEngine::new(layout.clone(), fused0.clone(), Loss::Mse, 4, 2, 8, 1);
    let ds = data::random_regression(8, 4, 2, &mut rng);
    let (x, y) = ds.batch(0, 8);
    let before = e.params_fused();
    for _ in 0..3 {
        e.evaluate(&x, &y);
        e.forward(&x);
    }
    let after = e.params_fused();
    assert_eq!(before.w1.max_abs_diff(&after.w1), 0.0);
    assert_eq!(before.b2.max_abs_diff(&after.b2), 0.0);
}
