//! Generative (property-style) integration tests: many random pools,
//! datasets and hyper-parameters; for each, the fused native engine must
//! reproduce per-model sequential training exactly — the paper's
//! independence claim swept across the configuration space.

use parallel_mlps::coordinator::BatchSet;
use parallel_mlps::data;
use parallel_mlps::nn::act::{Act, ALL_ACTS};
use parallel_mlps::nn::init::{extract_model, init_pool};
use parallel_mlps::nn::loss::{self, Loss};
use parallel_mlps::nn::mlp::MlpTrainer;
use parallel_mlps::nn::optimizer::OptimizerKind;
use parallel_mlps::nn::parallel::ParallelEngine;
use parallel_mlps::nn::stack::{stack_bits_equal, LayerStack, StackModel};
use parallel_mlps::pool::{PoolLayout, PoolSpec};
use parallel_mlps::tensor::kernels::{Kernel, KernelConfig};
use parallel_mlps::tensor::Tensor;
use parallel_mlps::util::rng::Rng;

fn random_pool(rng: &mut Rng) -> PoolSpec {
    let n = 1 + rng.below(10);
    let models: Vec<(u32, Act)> = (0..n)
        .map(|_| (1 + rng.below(9) as u32, ALL_ACTS[rng.below(10)]))
        .collect();
    PoolSpec::new(models).unwrap()
}

#[test]
fn fused_equals_sequential_across_random_configs() {
    let mut meta = Rng::new(0xF00D);
    for trial in 0..12 {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        let spec = random_pool(&mut rng);
        let f = 2 + rng.below(6);
        let o = 1 + rng.below(3);
        let b = [4usize, 8, 16][rng.below(3)];
        let n = b * (2 + rng.below(3));
        let lr = [0.01f32, 0.05, 0.1][rng.below(3)];
        let loss = if rng.below(2) == 0 { Loss::Mse } else { Loss::Ce };
        let epochs = 1 + rng.below(3);

        let layout = PoolLayout::build(&spec);
        let fused0 = init_pool(seed, &layout, f, o);
        let ds = if loss == Loss::Ce {
            data::blobs(n, f, o.max(2), &mut rng)
        } else {
            data::random_regression(n, f, o, &mut rng)
        };
        // CE blobs force out >= 2
        let o = ds.out_dim();
        let fused0 = if fused0.w2.shape()[0] != o { init_pool(seed, &layout, f, o) } else { fused0 };
        let batches = BatchSet::new(&ds, b, true).unwrap();

        let mut engine =
            ParallelEngine::new(layout.clone(), fused0.clone(), loss, f, o, b, 2);
        for _ in 0..epochs {
            for (x, y) in &batches.batches {
                engine.step(x, y, lr);
            }
        }
        let trained = engine.params_fused();

        for m in 0..spec.n_models() {
            let mut seq = MlpTrainer::new(
                extract_model(&fused0, &layout, m),
                spec.models()[m].1,
                loss,
                OptimizerKind::Sgd,
                1,
            );
            for _ in 0..epochs {
                for (x, y) in &batches.batches {
                    seq.step(x, y, lr);
                }
            }
            let got = extract_model(&trained, &layout, m);
            let diff = got.max_abs_diff(&seq.params);
            assert!(
                diff < 5e-4,
                "trial {trial} (seed {seed:#x}): model {m} of {:?} diverged by {diff} \
                 (f={f} o={o} b={b} lr={lr} loss={loss:?} epochs={epochs})",
                spec.models()[m]
            );
        }
    }
}

#[test]
fn random_layout_knobs_do_not_change_training() {
    // explicit (W, G) choices are a pure performance knob: results match
    let mut meta = Rng::new(0xBEEF);
    for _ in 0..6 {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        let spec = random_pool(&mut rng);
        let max_h = spec.max_hidden() as usize;
        let w = max_h.max(4 + rng.below(24)).div_ceil(4) * 4;
        let g = 1 + rng.below(8);
        let (f, o, b) = (4usize, 2usize, 8usize);
        let ds = data::random_regression(16, f, o, &mut rng);
        let batches = BatchSet::new(&ds, b, true).unwrap();

        let run = |layout: PoolLayout| {
            let fused0 = init_pool(seed, &layout, f, o);
            let mut e = ParallelEngine::new(layout.clone(), fused0, Loss::Mse, f, o, b, 1);
            for (x, y) in &batches.batches {
                e.step(x, y, 0.05);
            }
            (0..layout.n_models())
                .map(|m| extract_model(&e.params_fused(), &layout, m))
                .collect::<Vec<_>>()
        };
        let a = run(PoolLayout::build(&spec));
        let b_ = run(PoolLayout::build_with(&spec, w, g));
        for (m, (pa, pb)) in a.iter().zip(&b_).enumerate() {
            let diff = pa.max_abs_diff(pb);
            assert!(diff < 1e-5, "seed {seed:#x} model {m}: layout knobs changed results ({diff})");
        }
    }
}

#[test]
fn blocked_kernel_training_is_bit_identical_to_naive_end_to_end() {
    // the full fused forward/backward under the Blocked kernel at
    // randomized pool specs: the kernel exactness contract promises not
    // "within tolerance" but bit-identity, so assert exactly that
    let mut meta = Rng::new(0xCAFE);
    for trial in 0..6 {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        let spec = random_pool(&mut rng);
        let (f, o, b) = (2 + rng.below(6), 1 + rng.below(3), 8usize);
        let layout = PoolLayout::build(&spec);
        let fused0 = init_pool(seed, &layout, f, o);
        let ds = data::random_regression(b * 3, f, o, &mut rng);
        let batches = BatchSet::new(&ds, b, true).unwrap();

        let run = |kernel: Kernel, threads: usize| {
            let mut e = ParallelEngine::new(layout.clone(), fused0.clone(), Loss::Mse, f, o, b, threads);
            e.set_kernel(kernel);
            let mut losses = Vec::new();
            for _ in 0..2 {
                for (x, y) in &batches.batches {
                    losses = e.step(x, y, 0.05);
                }
            }
            (e.params_fused(), losses)
        };
        let (p_naive, l_naive) = run(Kernel::Naive, 1);
        for threads in [1usize, 3] {
            let (p_blocked, l_blocked) = run(Kernel::Blocked, threads);
            for (tag, a, bt) in [
                ("w1", &p_naive.w1, &p_blocked.w1),
                ("b1", &p_naive.b1, &p_blocked.b1),
                ("w2", &p_naive.w2, &p_blocked.w2),
                ("b2", &p_naive.b2, &p_blocked.b2),
            ] {
                assert!(
                    a.data().iter().zip(bt.data()).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "trial {trial} (seed {seed:#x}): {tag} diverged under the blocked kernel (t={threads})"
                );
            }
            for (m, (ln, lb)) in l_naive.iter().zip(&l_blocked).enumerate() {
                assert_eq!(ln.to_bits(), lb.to_bits(), "trial {trial} model {m} loss");
            }
        }
    }
}

#[test]
fn simd_kernel_training_stays_within_tolerance_of_naive_end_to_end() {
    // Tier-2 end-to-end contract: the simd kernel reassociates the
    // k-sum (FMA + 8-lane partials), so trained parameters and losses
    // drift from the naive run by rounding noise — but after full
    // training runs that drift must stay far below anything that could
    // change a model ranking. Same pool/data generator as the blocked
    // bit-identity test above; only the comparison relaxes.
    let mut meta = Rng::new(0xCAFF);
    for trial in 0..6 {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        let spec = random_pool(&mut rng);
        let (f, o, b) = (2 + rng.below(6), 1 + rng.below(3), 8usize);
        let layout = PoolLayout::build(&spec);
        let fused0 = init_pool(seed, &layout, f, o);
        let ds = data::random_regression(b * 3, f, o, &mut rng);
        let batches = BatchSet::new(&ds, b, true).unwrap();

        let run = |kernel: Kernel, threads: usize| {
            let mut e =
                ParallelEngine::new(layout.clone(), fused0.clone(), Loss::Mse, f, o, b, threads);
            e.set_kernel(kernel);
            let mut losses = Vec::new();
            for _ in 0..2 {
                for (x, y) in &batches.batches {
                    losses = e.step(x, y, 0.05);
                }
            }
            (e.params_fused(), losses)
        };
        let (p_naive, l_naive) = run(Kernel::Naive, 1);
        for threads in [1usize, 3] {
            let (p_simd, l_simd) = run(Kernel::Simd, threads);
            for (tag, a, s) in [
                ("w1", &p_naive.w1, &p_simd.w1),
                ("b1", &p_naive.b1, &p_simd.b1),
                ("w2", &p_naive.w2, &p_simd.w2),
                ("b2", &p_naive.b2, &p_simd.b2),
            ] {
                let diff = a.max_abs_diff(s);
                assert!(
                    diff < 5e-4,
                    "trial {trial} (seed {seed:#x}): {tag} drifted {diff} under simd (t={threads})"
                );
            }
            for (m, (ln, ls)) in l_naive.iter().zip(&l_simd).enumerate() {
                let tol = 1e-3 * (1.0 + ln.abs());
                assert!(
                    (ln - ls).abs() <= tol,
                    "trial {trial} model {m}: loss {ls} vs naive {ln} (t={threads})"
                );
            }
        }
    }
}

fn random_stack_pool(rng: &mut Rng) -> LayerStack {
    let n = 1 + rng.below(4);
    let models: Vec<StackModel> = (0..n)
        .map(|_| {
            let depth = 1 + rng.below(3);
            StackModel {
                hidden: (0..depth).map(|_| 1 + rng.below(7) as u32).collect(),
                act: ALL_ACTS[rng.below(10)],
            }
        })
        .collect();
    LayerStack::new(models, 4, 2).unwrap()
}

#[test]
fn blocked_kernel_stack_training_is_bit_identical_to_naive() {
    // same property for the arbitrary-depth layer stack (mixed depths,
    // identity passthrough, block-diagonal inner layers)
    let mut meta = Rng::new(0xDEED);
    for trial in 0..6 {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        let stack = random_stack_pool(&mut rng);
        let mut x = Tensor::zeros(&[10, 4]);
        rng.fill_normal(x.data_mut(), 0.0, 1.0);
        let mut y = Tensor::zeros(&[10, 2]);
        rng.fill_normal(y.data_mut(), 0.0, 1.0);

        let run = |kernel: Kernel, threads: usize| {
            let kcfg = KernelConfig::naive().with_kernel(kernel);
            let mut p = stack.init(seed);
            let mut losses = Vec::new();
            for _ in 0..3 {
                losses = stack.step_with(kcfg, &mut p, &x, &y, Loss::Mse, 0.05, threads);
            }
            (p, losses)
        };
        let (p_naive, l_naive) = run(Kernel::Naive, 1);
        for threads in [1usize, 4] {
            let (p_blocked, l_blocked) = run(Kernel::Blocked, threads);
            assert!(
                stack_bits_equal(&p_naive, &p_blocked),
                "trial {trial} (seed {seed:#x}): stack params diverged (t={threads})"
            );
            for (m, (ln, lb)) in l_naive.iter().zip(&l_blocked).enumerate() {
                assert_eq!(ln.to_bits(), lb.to_bits(), "trial {trial} model {m} loss");
            }
        }
    }
}

#[test]
fn simd_kernel_stack_training_stays_within_tolerance_of_naive() {
    // tier-2 analog of the stack bit-identity test: mixed depths,
    // identity passthrough and the packed block-diagonal path all under
    // the simd kernel, compared with a tolerance instead of bits
    let mut meta = Rng::new(0xDEEF);
    for trial in 0..6 {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        let stack = random_stack_pool(&mut rng);
        let mut x = Tensor::zeros(&[10, 4]);
        rng.fill_normal(x.data_mut(), 0.0, 1.0);
        let mut y = Tensor::zeros(&[10, 2]);
        rng.fill_normal(y.data_mut(), 0.0, 1.0);

        let run = |kernel: Kernel, threads: usize| {
            let kcfg = KernelConfig::naive().with_kernel(kernel);
            let mut p = stack.init(seed);
            let mut losses = Vec::new();
            for _ in 0..3 {
                losses = stack.step_with(kcfg, &mut p, &x, &y, Loss::Mse, 0.05, threads);
            }
            (p, losses)
        };
        let (p_naive, l_naive) = run(Kernel::Naive, 1);
        for threads in [1usize, 4] {
            let (p_simd, l_simd) = run(Kernel::Simd, threads);
            for (l, (ln, ls)) in p_naive.layers.iter().zip(&p_simd.layers).enumerate() {
                let dw = ln.w.max_abs_diff(&ls.w);
                let db = ln.b.max_abs_diff(&ls.b);
                assert!(
                    dw < 5e-4 && db < 5e-4,
                    "trial {trial} (seed {seed:#x}) layer {l}: simd drifted (w {dw}, b {db}, t={threads})"
                );
            }
            for (m, (ln, ls)) in l_naive.iter().zip(&l_simd).enumerate() {
                let tol = 1e-3 * (1.0 + ln.abs());
                assert!(
                    (ln - ls).abs() <= tol,
                    "trial {trial} model {m}: loss {ls} vs naive {ln} (t={threads})"
                );
            }
        }
    }
}

#[test]
fn blocked_kernel_gradients_match_finite_differences() {
    // property-style gradient check under the Blocked kernel: for
    // random smooth pools, the gradient implied by one SGD step
    // (g = (θ0 - θ1)/lr) must match the central finite difference of
    // the owning model's loss at randomly sampled coordinates
    let mut meta = Rng::new(0xFD01);
    for trial in 0..4 {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        // smooth activations only: ReLU-family kinks break FD locally
        let smooth = [Act::Tanh, Act::Sigmoid, Act::Gelu];
        let n = 1 + rng.below(3);
        let models: Vec<StackModel> = (0..n)
            .map(|_| {
                let depth = 1 + rng.below(3);
                StackModel {
                    hidden: (0..depth).map(|_| 1 + rng.below(5) as u32).collect(),
                    act: smooth[rng.below(3)],
                }
            })
            .collect();
        let stack = LayerStack::new(models, 3, 2).unwrap();
        let p0 = stack.init(seed);
        let mut x = Tensor::zeros(&[6, 3]);
        rng.fill_normal(x.data_mut(), 0.0, 1.0);
        let mut y = Tensor::zeros(&[6, 2]);
        rng.fill_normal(y.data_mut(), 0.0, 1.0);

        let blocked = KernelConfig::blocked();
        // one unit-lr step: p1 = p0 - 1.0 * grad, so grad = p0 - p1
        let mut p1 = p0.clone();
        stack.step_with(blocked, &mut p1, &x, &y, Loss::Mse, 1.0, 2);

        // summed per-model losses double as the scalar objective
        let loss_at = |p: &parallel_mlps::nn::stack::StackParams| -> f64 {
            let logits = stack.forward_with(blocked, p, &x, 2);
            (0..stack.n_models())
                .map(|m| loss::mlp_loss(Loss::Mse, &stack.model_logits(&logits, m), &y) as f64)
                .sum()
        };

        let mut checked = 0usize;
        for l in 0..p0.layers.len() {
            let len = p0.layers[l].w.len();
            for _ in 0..4 {
                let idx = rng.below(len.max(1));
                let g = (p0.layers[l].w.data()[idx] - p1.layers[l].w.data()[idx]) as f64;
                if g.abs() < 1e-2 {
                    continue; // too small to resolve against f32 eval noise
                }
                let eps = 5e-3f32;
                let mut plus = p0.clone();
                plus.layers[l].w.data_mut()[idx] += eps;
                let mut minus = p0.clone();
                minus.layers[l].w.data_mut()[idx] -= eps;
                let fd = (loss_at(&plus) - loss_at(&minus)) / (2.0 * eps as f64);
                let rel = (fd - g).abs() / g.abs().max(1e-3);
                assert!(
                    rel < 0.15,
                    "trial {trial} (seed {seed:#x}) layer {l} idx {idx}: analytic {g:.6} vs fd {fd:.6} (rel {rel:.3})"
                );
                checked += 1;
            }
        }
        assert!(checked >= 1, "trial {trial}: no resolvable coordinates");
    }
}

#[test]
fn evaluation_is_pure() {
    // evaluate() must not mutate parameters
    let mut rng = Rng::new(77);
    let spec = random_pool(&mut rng);
    let layout = PoolLayout::build(&spec);
    let fused0 = init_pool(1, &layout, 4, 2);
    let mut e = ParallelEngine::new(layout.clone(), fused0.clone(), Loss::Mse, 4, 2, 8, 1);
    let ds = data::random_regression(8, 4, 2, &mut rng);
    let (x, y) = ds.batch(0, 8);
    let before = e.params_fused();
    for _ in 0..3 {
        e.evaluate(&x, &y);
        e.forward(&x);
    }
    let after = e.params_fused();
    assert_eq!(before.w1.max_abs_diff(&after.w1), 0.0);
    assert_eq!(before.b2.max_abs_diff(&after.b2), 0.0);
}
