//! Integration tests for the unified `PoolEngine` + `TrainSession` API:
//! the paper's independence claim must survive the abstraction — driving
//! native parallel and native sequential through the SAME generic loop
//! yields identical losses, params and validation rankings.

use parallel_mlps::config::{ExperimentConfig, Strategy};
use parallel_mlps::coordinator::{
    run_experiment, BatchSet, DeepEngine, EarlyStop, PoolEngine, SequentialEngine, TrainSession,
};
use parallel_mlps::data;
use parallel_mlps::nn::act::Act;
use parallel_mlps::nn::init::init_pool;
use parallel_mlps::nn::stack::{DenseStack, LayerStack, StackModel};
use parallel_mlps::nn::loss::Loss;
use parallel_mlps::nn::optimizer::OptimizerKind;
use parallel_mlps::nn::parallel::ParallelEngine;
use parallel_mlps::pool::{PoolLayout, PoolSpec};
use parallel_mlps::util::rng::Rng;

const F: usize = 5;
const O: usize = 2;
const B: usize = 8;
const SEED: u64 = 2024;

fn pool() -> PoolSpec {
    PoolSpec::new(vec![
        (2, Act::Sigmoid),
        (3, Act::Relu),
        (1, Act::Identity),
        (4, Act::Tanh),
    ])
    .unwrap()
}

/// THE agreement test: both native strategies through `&mut dyn
/// PoolEngine` + one `TrainSession`, seeded, to identical losses.
#[test]
fn engine_agreement_native_parallel_vs_sequential() {
    let spec = pool();
    let layout = PoolLayout::build(&spec);
    let fused = init_pool(SEED, &layout, F, O);
    let mut rng = Rng::new(SEED);
    let ds = data::random_regression(48, F, O, &mut rng);
    let split = ds.split(0.7, 0.15, &mut rng);
    let batches = BatchSet::new(&split.train, B, true).unwrap();

    let session = || {
        TrainSession::builder()
            .val_data(&split.val)
            .epochs(4)
            .warmup(1)
            .lr(0.05)
    };

    let mut par: Box<dyn PoolEngine> = Box::new(ParallelEngine::new(
        layout.clone(),
        fused.clone(),
        Loss::Mse,
        F,
        O,
        B,
        2,
    ));
    let rep_par = session().run_with_batches(par.as_mut(), &batches).unwrap();

    let mut seq: Box<dyn PoolEngine> = Box::new(SequentialEngine::from_pool(
        &spec,
        &layout,
        &fused,
        Loss::Mse,
        OptimizerKind::Sgd,
    ));
    let rep_seq = session().run_with_batches(seq.as_mut(), &batches).unwrap();

    assert_eq!(rep_par.engine, "native_parallel");
    assert_eq!(rep_seq.engine, "native_sequential");
    assert_eq!(rep_par.n_models, rep_seq.n_models);
    assert_eq!(rep_par.outcome.epoch_times.len(), rep_seq.outcome.epoch_times.len());

    // identical final training losses per model
    for (m, (a, b)) in rep_par
        .outcome
        .final_losses
        .iter()
        .zip(&rep_seq.outcome.final_losses)
        .enumerate()
    {
        assert!((a - b).abs() < 1e-5, "model {m}: {a} vs {b}");
    }
    // identical validation losses per model
    let vp = rep_par.outcome.val_losses.as_ref().unwrap();
    let vs = rep_seq.outcome.val_losses.as_ref().unwrap();
    for (m, (a, b)) in vp.iter().zip(vs).enumerate() {
        assert!((a - b).abs() < 1e-4, "model {m} val: {a} vs {b}");
    }
    // identical trained parameters per model
    for m in 0..spec.n_models() {
        let a = par.extract(m).unwrap().shallow().unwrap();
        let b = seq.extract(m).unwrap().shallow().unwrap();
        let diff = a.max_abs_diff(&b);
        assert!(diff < 1e-4, "model {m}: params diverged by {diff}");
    }
}

/// The deep engine through the same generic loop matches the explicit
/// per-model dense reference trainer — with heterogeneous DEPTHS (2 and
/// 3 hidden layers) fused into one pool.
#[test]
fn deep_engine_matches_dense_reference_through_session() {
    let stack = LayerStack::new(
        vec![
            StackModel { hidden: vec![2, 3], act: Act::Tanh },
            StackModel { hidden: vec![3, 2, 2], act: Act::Relu },
        ],
        F,
        O,
    )
    .unwrap();
    let mut engine = DeepEngine::new(stack, 11, Loss::Mse, 2);
    // dense references from the same init, BEFORE training
    let mut refs: Vec<DenseStack> = (0..2)
        .map(|m| {
            engine
                .extract(m)
                .unwrap()
                .stacked()
                .expect("deep engine must extract stacked params")
        })
        .collect();

    let mut rng = Rng::new(77);
    let ds = data::random_regression(32, F, O, &mut rng);
    let batches = BatchSet::new(&ds, B, true).unwrap();
    let rep = TrainSession::builder()
        .epochs(3)
        .lr(0.05)
        .run_with_batches(&mut engine, &batches)
        .unwrap();

    for (m, r) in refs.iter_mut().enumerate() {
        let mut last = 0.0;
        for _ in 0..3 {
            for (x, y) in &batches.batches {
                last = r.step(x, y, Loss::Mse, 0.05);
            }
        }
        assert!(
            (rep.outcome.final_losses[m] - last).abs() < 1e-5,
            "model {m}: fused {} vs reference {last}",
            rep.outcome.final_losses[m]
        );
        // trained params agree too, at each model's own depth
        let trained = engine.extract(m).unwrap().stacked().unwrap();
        let diff = trained.max_abs_diff(r);
        assert!(diff < 1e-4, "model {m}: params diverged by {diff}");
    }
}

/// Early stopping cuts training short on a stalled run and reports it.
#[test]
fn early_stop_triggers_and_reports() {
    let spec = pool();
    let layout = PoolLayout::build(&spec);
    let fused = init_pool(3, &layout, F, O);
    let mut rng = Rng::new(5);
    let ds = data::random_regression(32, F, O, &mut rng);
    // lr = 0: losses are flat, patience 2 stops after 3 epochs
    let mut engine = ParallelEngine::new(layout, fused, Loss::Mse, F, O, B, 1);
    let rep = TrainSession::builder()
        .train_data(&ds)
        .batches(B, true)
        .epochs(20)
        .lr(0.0)
        .observer(Box::new(EarlyStop::new(2)))
        .run(&mut engine)
        .unwrap();
    assert!(rep.stopped_early);
    assert_eq!(rep.epochs_run, vec![3]);
    assert_eq!(rep.outcome.epoch_times.len(), 3);
    assert_eq!(rep.outcome.train_curve.points.len(), 3);
}

/// Early stopping on a healthy run with generous patience never fires.
#[test]
fn early_stop_does_not_trigger_when_improving() {
    let spec = pool();
    let layout = PoolLayout::build(&spec);
    let fused = init_pool(4, &layout, F, O);
    let mut rng = Rng::new(6);
    let ds = data::teacher_mlp(48, F, O, 3, &mut rng);
    let mut engine = ParallelEngine::new(layout, fused, Loss::Mse, F, O, B, 1);
    let rep = TrainSession::builder()
        .train_data(&ds)
        .batches(B, true)
        .epochs(6)
        .lr(0.05)
        .observer(Box::new(EarlyStop::new(6)))
        .run(&mut engine)
        .unwrap();
    assert!(!rep.stopped_early);
    assert_eq!(rep.epochs_run, vec![6]);
}

/// `run_experiment` routes every native strategy (including the new
/// deep_native) through the same trait + session, with agreeing signals.
#[test]
fn all_native_strategies_route_through_run_experiment() {
    let base = ExperimentConfig {
        dataset: data::SynthKind::TeacherMlp,
        samples: 120,
        features: F,
        out: O,
        teacher_hidden: 4,
        hidden_sizes: vec![2, 4],
        acts: vec![Act::Tanh],
        epochs: 5,
        warmup_epochs: 1,
        batch: 20,
        lr: 0.05,
        loss: Loss::Mse,
        threads: 2,
        seed: 11,
        ..Default::default()
    };
    let par = run_experiment(&base).unwrap();
    let seq = run_experiment(&ExperimentConfig {
        strategy: Strategy::NativeSequential,
        ..base.clone()
    })
    .unwrap();
    let deep = run_experiment(&ExperimentConfig {
        strategy: Strategy::DeepNative,
        early_stop: Some(3),
        ..base.clone()
    })
    .unwrap();
    // shallow engines agree exactly
    let vp = par.outcome.val_losses.as_ref().unwrap();
    let vs = seq.outcome.val_losses.as_ref().unwrap();
    for (a, b) in vp.iter().zip(vs) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
    // the deep pool is a different architecture — just require sane output
    assert_eq!(deep.ranked.len(), 2);
    assert!(deep.outcome.val_losses.as_ref().unwrap().iter().all(|v| v.is_finite()));
    assert!(deep.outcome.epoch_times.len() <= 5);
}
