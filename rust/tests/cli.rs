//! CLI integration: drive the `pmlp` binary end-to-end as a user would.

use std::path::{Path, PathBuf};
use std::process::Command;

fn pmlp() -> PathBuf {
    // cargo puts integration-test binaries next to the main ones
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // debug/ or release/
    p.push(format!("pmlp{}", std::env::consts::EXE_SUFFIX));
    p
}

fn have_artifacts() -> bool {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json").exists()
}

#[test]
fn help_prints_usage() {
    let out = Command::new(pmlp()).arg("help").output().unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("USAGE"));
    assert!(stdout.contains("selftest"));
}

#[test]
fn unknown_subcommand_fails() {
    let out = Command::new(pmlp()).arg("zap").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn selftest_passes() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let out = Command::new(pmlp()).arg("selftest").output().unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("selftest PASSED"), "{stdout}");
}

#[test]
fn inspect_reports_pools() {
    let out = Command::new(pmlp()).arg("inspect").output().unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("paper (10k)"));
    assert!(stdout.contains("pad_eff"));
}

#[test]
fn train_with_config_file() {
    let tmp = std::env::temp_dir().join(format!("pmlp_cfg_{}.toml", std::process::id()));
    std::fs::write(
        &tmp,
        r#"
[experiment]
name = "cli_test"
dataset = "blobs"
samples = 150
features = 6
out = 2
hidden_sizes = [2, 4]
acts = ["relu"]
epochs = 5
warmup_epochs = 1
batch = 25
lr = 0.2
loss = "ce"
strategy = "native_parallel"
threads = 2
seed = 5
"#,
    )
    .unwrap();
    let out = Command::new(pmlp())
        .args(["train", "--config", tmp.to_str().unwrap(), "--top", "3"])
        .output()
        .unwrap();
    std::fs::remove_file(&tmp).ok();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("Top-"), "{stdout}");
    assert!(stdout.contains("relu"), "{stdout}");
}

#[test]
fn train_rejects_missing_config() {
    // no --config and no --strategy: nothing to train
    let out = Command::new(pmlp()).args(["train"]).output().unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--config"), "{stderr}");
}

#[test]
fn train_deep_native_with_early_stop_end_to_end() {
    // the acceptance path: config-free CLI run of the fifth strategy
    // through the unified TrainSession + PoolEngine loop
    let out = Command::new(pmlp())
        .args([
            "train",
            "--strategy",
            "deep_native",
            "--early-stop",
            "5",
            "--dataset",
            "blobs",
            "--samples",
            "200",
            "--features",
            "6",
            "--epochs",
            "6",
            "--batch",
            "25",
            "--top",
            "3",
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("deep_native"), "{stdout}");
    assert!(stdout.contains("early-stop patience 5"), "{stdout}");
    assert!(stdout.contains("Top-"), "{stdout}");
}

#[test]
fn rank_prints_only_the_table() {
    let out = Command::new(pmlp())
        .args([
            "rank", "--strategy", "native_parallel", "--dataset", "blobs", "--samples", "160",
            "--features", "6", "--epochs", "3", "--batch", "20", "--top", "4",
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("Top-4"), "{stdout}");
    assert!(stdout.contains("val_loss"), "{stdout}");
    // rank is the machine-friendly view: no training prose around it
    assert!(!stdout.contains("trained"), "{stdout}");
}

#[test]
fn export_then_serve_bench_from_checkpoint() {
    let ckpt = std::env::temp_dir().join(format!("pmlp_cli_ckpt_{}.bin", std::process::id()));
    let out = Command::new(pmlp())
        .args([
            "export", "--strategy", "native_parallel", "--dataset", "blobs", "--samples", "160",
            "--features", "6", "--epochs", "3", "--batch", "20", "--top", "3", "--out",
            ckpt.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("checkpoint:"), "{stdout}");
    assert!(stdout.contains("winners extracted"), "{stdout}");
    let bytes = std::fs::read(&ckpt).unwrap();
    assert!(bytes.starts_with(b"PMLPCKPT"), "bad magic in exported file");

    // serve the exported winner under a quick load
    let out2 = Command::new(pmlp())
        .args([
            "serve-bench", "--ckpt", ckpt.to_str().unwrap(), "--rows", "128", "--clients", "2",
            "--depth", "8", "--batch-sizes", "1,4",
        ])
        .output()
        .unwrap();
    std::fs::remove_file(&ckpt).ok();
    let stdout2 = String::from_utf8_lossy(&out2.stdout);
    let stderr2 = String::from_utf8_lossy(&out2.stderr);
    assert!(out2.status.success(), "stdout:\n{stdout2}\nstderr:\n{stderr2}");
    assert!(stdout2.contains("checkpoint winner"), "{stdout2}");
    assert!(stdout2.contains("rows/s"), "{stdout2}");
}

#[test]
fn export_deep_mixed_depths_then_serve_bench() {
    // the acceptance path at the CLI surface: a mixed-depth deep pool
    // trains, exports a v2 checkpoint, and its winner serves
    let ckpt = std::env::temp_dir().join(format!("pmlp_cli_deep_{}.ckpt", std::process::id()));
    let out = Command::new(pmlp())
        .args([
            "export", "--strategy", "deep_native", "--depths", "2,3", "--dataset", "blobs",
            "--samples", "160", "--features", "6", "--epochs", "3", "--batch", "20", "--top",
            "3", "--out", ckpt.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("depth 3"), "{stdout}");
    assert!(stdout.contains("winners extracted"), "{stdout}");
    let bytes = std::fs::read(&ckpt).unwrap();
    assert!(bytes.starts_with(b"PMLPCKPT"), "bad magic in exported file");

    let out2 = Command::new(pmlp())
        .args([
            "serve-bench", "--ckpt", ckpt.to_str().unwrap(), "--rows", "64", "--clients", "2",
            "--depth", "4", "--batch-sizes", "1,4",
        ])
        .output()
        .unwrap();
    std::fs::remove_file(&ckpt).ok();
    let stdout2 = String::from_utf8_lossy(&out2.stdout);
    let stderr2 = String::from_utf8_lossy(&out2.stderr);
    assert!(out2.status.success(), "stdout:\n{stdout2}\nstderr:\n{stderr2}");
    assert!(stdout2.contains("checkpoint winner"), "{stdout2}");
    assert!(stdout2.contains("hidden layer"), "{stdout2}");
}

#[test]
fn train_deep_with_depths_flag() {
    let out = Command::new(pmlp())
        .args([
            "train", "--strategy", "deep_native", "--depths", "1,3", "--dataset", "blobs",
            "--samples", "150", "--features", "6", "--epochs", "3", "--batch", "25", "--top",
            "4",
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("deep_native"), "{stdout}");
    assert!(stdout.contains("Top-"), "{stdout}");
    // mixed depths are invisible in the (h, act) table: the architecture
    // lines must disambiguate them
    assert!(stdout.contains("architectures (top-"), "{stdout}");
    assert!(stdout.contains("hidden layer(s)"), "{stdout}");
}

#[test]
fn train_bench_writes_json_report() {
    let json = std::env::temp_dir().join(format!("pmlp_trainbench_{}.json", std::process::id()));
    let out = Command::new(pmlp())
        .args([
            "train-bench", "--quick", "--samples", "128", "--epochs", "2", "--warmup", "1",
            "--batch", "32", "--threads", "2", "--out", json.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("models/s"), "{stdout}");
    let doc = std::fs::read_to_string(&json).unwrap();
    std::fs::remove_file(&json).ok();
    let v = parallel_mlps::util::json::parse(&doc).expect("train-bench JSON must parse");
    assert_eq!(v.req("bench").unwrap().as_str(), Some("train"));
    let runs = v.req("runs").unwrap().as_arr().unwrap();
    // shallow, depth-2, depth-3 under BOTH kernels (naive then blocked)
    assert_eq!(runs.len(), 6);
    let depths: Vec<usize> =
        runs.iter().map(|r| r.req("depth").unwrap().as_usize().unwrap()).collect();
    assert_eq!(depths, vec![1, 2, 3, 1, 2, 3]);
    for r in runs {
        assert!(r.req("models_per_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.req("rows_per_s").unwrap().as_f64().unwrap() > 0.0);
    }
    // the halving column: 27-model pool, eta 3 x 1 epoch/rung = 40
    // model-epochs; the speedup is full_model_epochs / 40
    let h = v.req("halving").unwrap();
    assert_eq!(h.req("pool_models").unwrap().as_usize(), Some(27));
    assert_eq!(h.req("eta").unwrap().as_usize(), Some(3));
    assert_eq!(h.req("halving_model_epochs").unwrap().as_usize(), Some(40));
    let full_me = h.req("full_model_epochs").unwrap().as_usize().unwrap();
    assert_eq!(full_me, 27 * 2); // --epochs 2 in this invocation
    let speedup = h.req("search_speedup").unwrap().as_f64().unwrap();
    assert!((speedup - full_me as f64 / 40.0).abs() < 1e-3, "{speedup}");
    assert!(h.req("archs_per_s_halving").unwrap().as_f64().unwrap() > 0.0);
    // at the default 8-epoch budget the same schedule is 216/40 = 5.4x,
    // comfortably past the 3x acceptance floor (pure arithmetic)
    assert!(27.0 * 8.0 / 40.0 >= 3.0);
}

/// Tiny pool config so halving smoke tests have deterministic grids.
fn small_grid_toml(name: &str) -> std::path::PathBuf {
    let path = std::env::temp_dir().join(format!("pmlp_{name}_{}.toml", std::process::id()));
    std::fs::write(&path, "[experiment]\nhidden_sizes = [2, 4]\nacts = [\"relu\", \"tanh\"]\n")
        .unwrap();
    path
}

#[test]
fn rank_halving_prints_schedule_and_full_table() {
    let toml = small_grid_toml("rank_halve");
    let out = Command::new(pmlp())
        .args([
            "rank", "--config", toml.to_str().unwrap(), "--strategy", "native_parallel",
            "--dataset", "blobs", "--samples", "160", "--features", "6", "--epochs", "6",
            "--batch", "20", "--halving", "--eta", "2", "--rung-epochs", "1", "--top", "4",
            "--threads", "2",
        ])
        .output()
        .unwrap();
    std::fs::remove_file(&toml).ok();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    // 2 hidden x 2 acts = 4 models; the table must still rank EVERY
    // original model, survivors and retirees alike
    assert!(stdout.contains("Top-4"), "{stdout}");
    assert!(stdout.contains("val_"), "{stdout}");
    // schedule context goes to stderr, keeping stdout machine-friendly
    assert!(stderr.contains("halving: eta 2"), "{stderr}");
    assert!(stderr.contains("architectures per budget"), "{stderr}");
    assert!(!stdout.contains("trained"), "{stdout}");
}

#[test]
fn rank_halving_composes_with_csv_and_folds() {
    let data = blossom();
    let out = Command::new(pmlp())
        .args([
            "rank", "--data", data.as_str(), "--target", "species", "--epochs", "4", "--batch",
            "25", "--folds", "2", "--halving", "--eta", "3", "--rung-epochs", "1", "--top", "3",
            "--threads", "2",
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("Top-3"), "{stdout}");
    assert!(stdout.contains("val_acc"), "{stdout}");
    assert!(stderr.contains("2 fold arms"), "{stderr}");
    assert!(stderr.contains("halving: eta 3"), "{stderr}");
}

#[test]
fn export_halving_writes_servable_checkpoint() {
    let toml = small_grid_toml("export_halve");
    let ckpt = std::env::temp_dir().join(format!("pmlp_cli_halve_{}.ckpt", std::process::id()));
    let out = Command::new(pmlp())
        .args([
            "export", "--config", toml.to_str().unwrap(), "--strategy", "deep_native",
            "--depths", "1,2", "--dataset", "blobs", "--samples", "160", "--features", "6",
            "--epochs", "4", "--batch", "20", "--halving", "--eta", "2", "--top", "3", "--out",
            ckpt.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    std::fs::remove_file(&toml).ok();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    // the checkpoint holds the FULL original pool (2 hidden x 2 acts x 2
    // depths = 8 models), not just the halving survivors
    assert!(stdout.contains("8 models"), "{stdout}");
    assert!(stdout.contains("roundtrip verified"), "{stdout}");
    assert!(stdout.contains("winners extracted"), "{stdout}");
    let bytes = std::fs::read(&ckpt).unwrap();
    assert!(bytes.starts_with(b"PMLPCKPT"), "bad magic in exported file");

    let out2 = Command::new(pmlp())
        .args([
            "serve-bench", "--ckpt", ckpt.to_str().unwrap(), "--rows", "64", "--clients", "2",
            "--depth", "4", "--batch-sizes", "1,4",
        ])
        .output()
        .unwrap();
    std::fs::remove_file(&ckpt).ok();
    let stdout2 = String::from_utf8_lossy(&out2.stdout);
    let stderr2 = String::from_utf8_lossy(&out2.stderr);
    assert!(out2.status.success(), "stdout:\n{stdout2}\nstderr:\n{stderr2}");
    assert!(stdout2.contains("checkpoint winner"), "{stdout2}");
}

#[test]
fn export_halving_rejects_folds() {
    let out = Command::new(pmlp())
        .args([
            "export", "--strategy", "native_parallel", "--dataset", "blobs", "--samples", "160",
            "--features", "6", "--epochs", "4", "--folds", "2", "--halving",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("rank --halving --folds"), "{stderr}");
}

#[test]
fn halving_knobs_require_the_flag() {
    let out = Command::new(pmlp())
        .args([
            "rank", "--strategy", "native_parallel", "--dataset", "blobs", "--samples", "160",
            "--features", "6", "--eta", "3",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--halving"), "{stderr}");
}

#[test]
fn serve_bench_synthetic_writes_json_report() {
    let json = std::env::temp_dir().join(format!("pmlp_serve_{}.json", std::process::id()));
    let out = Command::new(pmlp())
        .args([
            "serve-bench", "--rows", "96", "--clients", "2", "--depth", "8", "--batch-sizes",
            "1,8", "--hidden", "32", "--features", "16", "--out-dim", "4", "--out",
            json.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("max_batch"), "{stdout}");
    let doc = std::fs::read_to_string(&json).unwrap();
    std::fs::remove_file(&json).ok();
    let v = parallel_mlps::util::json::parse(&doc).expect("serve-bench JSON must parse");
    assert_eq!(v.req("bench").unwrap().as_str(), Some("serve"));
    assert_eq!(v.req("runs").unwrap().as_arr().unwrap().len(), 2);
}

fn blossom() -> String {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("examples/data/blossom.csv")
        .to_str()
        .unwrap()
        .to_string()
}

#[test]
fn train_on_csv_with_kfold_ranking() {
    let data = blossom();
    let out = Command::new(pmlp())
        .args([
            "train", "--data", data.as_str(), "--target", "species", "--epochs", "3", "--batch",
            "25", "--folds", "2", "--top", "3", "--threads", "2",
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("blossom.csv"), "{stdout}");
    assert!(stdout.contains("2-fold cross-validation"), "{stdout}");
    assert!(stdout.contains("Top-3"), "{stdout}");
    assert!(stdout.contains("val_acc"), "{stdout}");
}

#[test]
fn rank_on_csv_prints_only_the_table() {
    let data = blossom();
    let out = Command::new(pmlp())
        .args([
            "rank", "--data", data.as_str(), "--target", "species", "--epochs", "3", "--batch",
            "25", "--folds", "2", "--top", "4", "--threads", "2",
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("Top-4"), "{stdout}");
    assert!(!stdout.contains("trained"), "{stdout}");
    // fold context goes to stderr, keeping stdout machine-friendly
    assert!(stderr.contains("2-fold CV"), "{stderr}");
}

#[test]
fn export_csv_embeds_preprocessor_then_serve_bench_replays_it() {
    let data = blossom();
    let ckpt = std::env::temp_dir().join(format!("pmlp_cli_csv_{}.ckpt", std::process::id()));
    let out = Command::new(pmlp())
        .args([
            "export", "--data", data.as_str(), "--target", "species", "--epochs", "3", "--batch",
            "25", "--top", "2", "--threads", "2", "--out", ckpt.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("preprocessor embedded"), "{stdout}");
    assert!(stdout.contains("3 classes"), "{stdout}");
    assert!(stdout.contains("checkpoint:"), "{stdout}");

    // replay the SAME csv through the micro-batch server
    let out2 = Command::new(pmlp())
        .args([
            "serve-bench", "--ckpt", ckpt.to_str().unwrap(), "--data", data.as_str(), "--rows",
            "64", "--clients", "2", "--depth", "4", "--batch-sizes", "1,4",
        ])
        .output()
        .unwrap();
    std::fs::remove_file(&ckpt).ok();
    let stdout2 = String::from_utf8_lossy(&out2.stdout);
    let stderr2 = String::from_utf8_lossy(&out2.stderr);
    assert!(out2.status.success(), "stdout:\n{stdout2}\nstderr:\n{stderr2}");
    assert!(stdout2.contains("replaying 150 rows"), "{stdout2}");
    assert!(stdout2.contains("checkpoint preprocessor"), "{stdout2}");
    assert!(stdout2.contains("rows/s"), "{stdout2}");
}

#[test]
fn train_data_requires_target() {
    let out = Command::new(pmlp())
        .args(["train", "--data", "whatever.csv"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--target"), "{stderr}");
}

#[test]
fn train_csv_reports_missing_target_column_with_candidates() {
    let data = blossom();
    let out = Command::new(pmlp())
        .args(["train", "--data", data.as_str(), "--target", "nope"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("\"nope\"") && stderr.contains("species"), "{stderr}");
}

#[test]
fn train_rejects_depths_on_shallow_strategy() {
    let out = Command::new(pmlp())
        .args(["train", "--strategy", "native_parallel", "--depths", "2,3"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("deep_native"), "{stderr}");
}

#[test]
fn train_rejects_unknown_strategy() {
    let out = Command::new(pmlp())
        .args(["train", "--strategy", "warp_drive"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown strategy"), "{stderr}");
}

#[test]
fn bench_rejects_bad_table() {
    let out = Command::new(pmlp()).args(["bench", "--table", "9"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn traced_pipeline_summarizes_with_balanced_spans() {
    // the observability acceptance path at the CLI surface: train and
    // serve-bench append to ONE trace file (the sink opens it in append
    // mode), and `trace summarize` folds it strictly — any unparseable
    // line or unbalanced span would fail the subcommand
    let trace = std::env::temp_dir().join(format!("pmlp_cli_trace_{}.jsonl", std::process::id()));
    std::fs::remove_file(&trace).ok(); // fresh trace, not an append to an old run
    let data = blossom();
    let out = Command::new(pmlp())
        .args([
            "train", "--data", data.as_str(), "--target", "species", "--epochs", "3",
            "--batch", "25", "--threads", "2", "--trace", trace.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stderr:\n{stderr}");
    assert!(stderr.contains("tracing to"), "{stderr}");

    let out2 = Command::new(pmlp())
        .args([
            "serve-bench", "--hidden", "8", "--features", "6", "--out-dim", "3", "--rows",
            "64", "--clients", "2", "--depth", "4", "--batch-sizes", "4", "--trace",
            trace.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    let stderr2 = String::from_utf8_lossy(&out2.stderr);
    assert!(out2.status.success(), "stderr:\n{stderr2}");

    // every line of the combined two-process trace must be JSON
    let text = std::fs::read_to_string(&trace).unwrap();
    assert!(!text.trim().is_empty());
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        parallel_mlps::util::json::parse(line)
            .unwrap_or_else(|e| panic!("trace line is not JSON: {e}\n{line}"));
    }

    let out3 = Command::new(pmlp())
        .args(["trace", "summarize", trace.to_str().unwrap()])
        .output()
        .unwrap();
    std::fs::remove_file(&trace).ok();
    let stdout3 = String::from_utf8_lossy(&out3.stdout);
    let stderr3 = String::from_utf8_lossy(&out3.stderr);
    assert!(out3.status.success(), "stdout:\n{stdout3}\nstderr:\n{stderr3}");
    assert!(stdout3.contains("train.epoch"), "{stdout3}");
    assert!(stdout3.contains("serve.batch"), "{stdout3}");
    assert!(stdout3.contains("all spans balanced"), "{stdout3}");
}

#[test]
fn trace_summarize_rejects_garbage_and_missing_files() {
    let out = Command::new(pmlp())
        .args(["trace", "summarize", "/nonexistent/pmlp.jsonl"])
        .output()
        .unwrap();
    assert!(!out.status.success());

    let bad = std::env::temp_dir().join(format!("pmlp_cli_badtrace_{}.jsonl", std::process::id()));
    std::fs::write(&bad, "this is not json\n").unwrap();
    let out2 = Command::new(pmlp())
        .args(["trace", "summarize", bad.to_str().unwrap()])
        .output()
        .unwrap();
    std::fs::remove_file(&bad).ok();
    assert!(!out2.status.success());
    let stderr2 = String::from_utf8_lossy(&out2.stderr);
    assert!(stderr2.contains("line 1"), "{stderr2}");
}
