//! CLI integration: drive the `pmlp` binary end-to-end as a user would.

use std::path::{Path, PathBuf};
use std::process::Command;

fn pmlp() -> PathBuf {
    // cargo puts integration-test binaries next to the main ones
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // debug/ or release/
    p.push(format!("pmlp{}", std::env::consts::EXE_SUFFIX));
    p
}

fn have_artifacts() -> bool {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json").exists()
}

#[test]
fn help_prints_usage() {
    let out = Command::new(pmlp()).arg("help").output().unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("USAGE"));
    assert!(stdout.contains("selftest"));
}

#[test]
fn unknown_subcommand_fails() {
    let out = Command::new(pmlp()).arg("zap").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn selftest_passes() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let out = Command::new(pmlp()).arg("selftest").output().unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("selftest PASSED"), "{stdout}");
}

#[test]
fn inspect_reports_pools() {
    let out = Command::new(pmlp()).arg("inspect").output().unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("paper (10k)"));
    assert!(stdout.contains("pad_eff"));
}

#[test]
fn train_with_config_file() {
    let tmp = std::env::temp_dir().join(format!("pmlp_cfg_{}.toml", std::process::id()));
    std::fs::write(
        &tmp,
        r#"
[experiment]
name = "cli_test"
dataset = "blobs"
samples = 150
features = 6
out = 2
hidden_sizes = [2, 4]
acts = ["relu"]
epochs = 5
warmup_epochs = 1
batch = 25
lr = 0.2
loss = "ce"
strategy = "native_parallel"
threads = 2
seed = 5
"#,
    )
    .unwrap();
    let out = Command::new(pmlp())
        .args(["train", "--config", tmp.to_str().unwrap(), "--top", "3"])
        .output()
        .unwrap();
    std::fs::remove_file(&tmp).ok();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("Top-"), "{stdout}");
    assert!(stdout.contains("relu"), "{stdout}");
}

#[test]
fn train_rejects_missing_config() {
    // no --config and no --strategy: nothing to train
    let out = Command::new(pmlp()).args(["train"]).output().unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--config"), "{stderr}");
}

#[test]
fn train_deep_native_with_early_stop_end_to_end() {
    // the acceptance path: config-free CLI run of the fifth strategy
    // through the unified TrainSession + PoolEngine loop
    let out = Command::new(pmlp())
        .args([
            "train",
            "--strategy",
            "deep_native",
            "--early-stop",
            "5",
            "--dataset",
            "blobs",
            "--samples",
            "200",
            "--features",
            "6",
            "--epochs",
            "6",
            "--batch",
            "25",
            "--top",
            "3",
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("deep_native"), "{stdout}");
    assert!(stdout.contains("early-stop patience 5"), "{stdout}");
    assert!(stdout.contains("Top-"), "{stdout}");
}

#[test]
fn train_rejects_unknown_strategy() {
    let out = Command::new(pmlp())
        .args(["train", "--strategy", "warp_drive"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown strategy"), "{stderr}");
}

#[test]
fn bench_rejects_bad_table() {
    let out = Command::new(pmlp()).args(["bench", "--table", "9"]).output().unwrap();
    assert!(!out.status.success());
}
