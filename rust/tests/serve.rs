//! Inference subsystem end-to-end: checkpoint persistence and corruption
//! detection, winner-extraction equivalence against the fused pool,
//! registry loading, and micro-batched serving correctness/throughput.

use std::sync::Arc;

use parallel_mlps::io::{PoolCheckpoint, RankEntry};
use parallel_mlps::nn::act::Act;
use parallel_mlps::nn::init::init_pool;
use parallel_mlps::nn::loss::Loss;
use parallel_mlps::nn::parallel::ParallelEngine;
use parallel_mlps::nn::stack::stack_bits_equal;
use parallel_mlps::pool::{PoolLayout, PoolSpec};
use parallel_mlps::selection::rank_models;
use parallel_mlps::serve::bench::{run_load, synthetic_model, LoadSpec};
use parallel_mlps::serve::{ModelRegistry, ServableModel, ServeConfig, Server};
use parallel_mlps::tensor::kernels::{Kernel, KernelConfig};
use parallel_mlps::tensor::Tensor;
use parallel_mlps::util::rng::Rng;

const F: usize = 4;
const O: usize = 2;
const B: usize = 8;

fn smoke_spec() -> PoolSpec {
    PoolSpec::new(vec![
        (2, Act::Sigmoid),
        (3, Act::Relu),
        (2, Act::Tanh),
        (1, Act::Identity),
        (4, Act::Gelu),
    ])
    .unwrap()
}

/// A small fused pool trained for a few steps, plus the batch it saw.
fn trained_engine(steps: usize) -> (PoolSpec, PoolLayout, ParallelEngine, Tensor, Tensor) {
    let spec = smoke_spec();
    let layout = PoolLayout::build(&spec);
    let fused = init_pool(7, &layout, F, O);
    let mut engine = ParallelEngine::new(layout.clone(), fused, Loss::Mse, F, O, B, 1);
    let mut rng = Rng::new(3);
    let mut x = Tensor::zeros(&[B, F]);
    rng.fill_normal(x.data_mut(), 0.0, 1.0);
    let mut y = Tensor::zeros(&[B, O]);
    rng.fill_normal(y.data_mut(), 0.0, 1.0);
    for _ in 0..steps {
        engine.step(&x, &y, 0.05);
    }
    (spec, layout, engine, x, y)
}

fn ckpt_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("pmlp_serve_test_{tag}_{}.ckpt", std::process::id()))
}

#[test]
fn checkpoint_file_roundtrip_is_bit_exact() {
    let (_spec, layout, engine, _x, _y) = trained_engine(3);
    let ckpt = PoolCheckpoint::from_shallow(
        &layout,
        F,
        O,
        Loss::Mse,
        &engine.params_fused(),
        vec![RankEntry { index: 1, val_loss: 0.3, val_metric: 0.3 }],
    )
    .unwrap();
    let path = ckpt_path("roundtrip");
    ckpt.save(&path).unwrap();
    let back = PoolCheckpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(stack_bits_equal(&ckpt.params, &back.params));
    assert_eq!(back.models(), ckpt.models());
    assert_eq!(back.ranking, ckpt.ranking);
    assert_eq!(back.to_bytes(), ckpt.to_bytes());
}

#[test]
fn checkpoint_flipped_byte_on_disk_is_rejected() {
    let (_spec, layout, engine, _x, _y) = trained_engine(2);
    let ckpt =
        PoolCheckpoint::from_shallow(&layout, F, O, Loss::Mse, &engine.params_fused(), vec![])
            .unwrap();
    let path = ckpt_path("corrupt");
    ckpt.save(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01; // a single flipped bit in a tensor payload
    std::fs::write(&path, &bytes).unwrap();
    let err = PoolCheckpoint::load(&path).unwrap_err().to_string();
    std::fs::remove_file(&path).ok();
    assert!(err.contains("checksum"), "{err}");
}

#[test]
fn extracted_winner_matches_fused_pool_forward() {
    // the acceptance criterion: standalone forward of the extracted
    // model == the fused pool's logits for that model's slot, per row
    let (spec, layout, mut engine, x, y) = trained_engine(5);
    let (vl, vm) = engine.evaluate(&x, &y);
    let ranked = rank_models(&spec, &vl, &vm, Loss::Mse);
    let ckpt = PoolCheckpoint::from_engine(&engine, Loss::Mse, &ranked).unwrap();

    let fused_logits = engine.forward(&x); // [B, M_pad, O]
    for m in 0..spec.n_models() {
        let servable = ServableModel::from_checkpoint(&ckpt, m, format!("m{m}")).unwrap();
        assert_eq!(servable.act(), spec.models()[m].1);
        assert_eq!(servable.hidden(), spec.models()[m].0 as usize);
        assert_eq!(servable.depth(), 1);
        let pred = servable.predict(&x, 1);
        let slot = layout.slot[m];
        for bi in 0..x.rows() {
            for oi in 0..O {
                let fused = fused_logits.at3(bi, slot, oi);
                let standalone = pred.at2(bi, oi);
                assert!(
                    (fused - standalone).abs() < 1e-5,
                    "model {m} row {bi} out {oi}: fused {fused} vs standalone {standalone}"
                );
            }
        }
    }
}

#[test]
fn registry_serves_checkpoint_ranking() {
    let (spec, _layout, mut engine, x, y) = trained_engine(4);
    let (vl, vm) = engine.evaluate(&x, &y);
    let ranked = rank_models(&spec, &vl, &vm, Loss::Mse);
    let ckpt = PoolCheckpoint::from_engine(&engine, Loss::Mse, &ranked).unwrap();
    assert_eq!(ckpt.winner(), Some(ranked[0].index));

    let mut registry = ModelRegistry::new();
    let names = registry.load_top_k("pool", &ckpt, 3).unwrap();
    assert_eq!(names, vec!["pool/top1", "pool/top2", "pool/top3"]);
    let top1 = registry.get("pool/top1").unwrap();
    assert_eq!(top1.index, ranked[0].index);
    assert!((top1.val_loss - ranked[0].val_loss).abs() < 1e-6);
    assert!(registry.get("pool/top4").is_none());
}

#[test]
fn microbatched_predictions_match_direct_forward() {
    let model = synthetic_model(16, 8, 3, 9);
    let server =
        Server::start(model.clone(), ServeConfig { max_batch: 4, queue_cap: 64, threads: 1 })
            .unwrap();
    let mut handles = Vec::new();
    for c in 0..2u64 {
        let client = server.client();
        let model = model.clone();
        handles.push(std::thread::spawn(move || {
            let mut root = Rng::new(31);
            let mut rng = root.fork(c);
            for _ in 0..16 {
                let row: Vec<f32> = (0..8).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
                let got = client.predict(&row).unwrap();
                let want = model.predict(&Tensor::from_vec(row.clone(), &[1, 8]), 1);
                assert_eq!(got.len(), 3);
                for (g, w) in got.iter().zip(want.data()) {
                    assert!((g - w).abs() < 1e-6, "{g} vs {w}");
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let stats = server.shutdown();
    assert_eq!(stats.rows, 32);
    assert!(stats.batches >= 1 && stats.batches <= 32);
    assert!(stats.max_batch_seen >= 1 && stats.max_batch_seen <= 4);
}

#[test]
fn microbatching_beats_per_row_dispatch() {
    // the serve-side acceptance criterion: coalesced [B, F] forwards must
    // out-throughput B individual [1, F] dispatches on the same load
    let model = synthetic_model(256, 64, 8, 5);
    let spec = LoadSpec { rows_per_client: 384, clients: 4, depth: 32, seed: 1 };
    let unbatched = run_load(
        &model,
        ServeConfig { max_batch: 1, queue_cap: 4096, threads: 1 },
        &spec,
    )
    .unwrap();
    let batched = run_load(
        &model,
        ServeConfig { max_batch: 64, queue_cap: 4096, threads: 1 },
        &spec,
    )
    .unwrap();
    assert_eq!(unbatched.rows, 4 * 384);
    assert_eq!(batched.rows, 4 * 384);
    assert!(
        batched.mean_batch > 1.0,
        "load generator produced no coalescing: {batched:?}"
    );
    assert!(
        batched.rows_per_s > unbatched.rows_per_s,
        "micro-batched {:.0} rows/s <= per-row {:.0} rows/s",
        batched.rows_per_s,
        unbatched.rows_per_s
    );
}

// ---------------------------------------------------------------------------
// Golden-fixture regression: the committed PMLPCKPT v3 file
// ---------------------------------------------------------------------------

/// The frozen v3 checkpoint authored by `tools/make_golden_fixture.py`.
/// All weights and inputs are small integers, so every forward output is
/// exact integer arithmetic in f32 — bit-stable under ANY kernel, thread
/// count or summation order. If checkpoint parsing, extraction or the
/// inference path ever drifts, these asserts (and the byte-for-byte
/// re-encode below) catch it before a release does.
const GOLDEN_CKPT: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/fixtures/golden_v3.ckpt");

/// `[4, 3]` integer probe batch (mirrored in the generator script).
const GOLDEN_X: [f32; 12] = [1.0, 0.0, -1.0, 0.0, 2.0, 1.0, -1.0, 1.0, 0.0, 2.0, -1.0, 1.0];
/// Expected `[4, 2]` logits for model 0 (hidden [2], ReLU).
const GOLDEN_Y_M0: [f32; 8] = [5.0, -2.0, 1.0, -1.0, 1.0, -1.0, 5.0, -5.0];
/// Expected `[4, 2]` logits for model 1 (hidden [3, 2], Identity) — the
/// stored winner.
const GOLDEN_Y_M1: [f32; 8] = [-11.0, -2.0, 0.0, 8.0, -4.0, 6.0, 1.0, -8.0];

fn assert_bits(got: &Tensor, want: &[f32], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}: shape");
    for (i, (g, w)) in got.data().iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{tag}: element {i}: {g} vs {w}");
    }
}

#[test]
fn golden_v3_fixture_loads_and_reencodes_byte_identically() {
    let bytes = std::fs::read(GOLDEN_CKPT).unwrap();
    let ckpt = PoolCheckpoint::from_bytes(&bytes).unwrap();
    // canonical serialization: the current writer must reproduce the
    // committed file byte for byte, or checkpoint compat has drifted
    assert_eq!(ckpt.to_bytes(), bytes, "v3 writer no longer reproduces the golden fixture");

    assert_eq!(ckpt.n_models(), 2);
    assert_eq!(ckpt.features(), 3);
    assert_eq!(ckpt.out(), 2);
    assert_eq!(ckpt.depth(), 2);
    assert_eq!(ckpt.loss.name(), "mse");
    assert!(ckpt.preprocessor.is_none());
    assert_eq!(ckpt.winner(), Some(1));
    assert_eq!(ckpt.ranking.len(), 2);
    assert_eq!(ckpt.ranking[0].val_loss.to_bits(), 0.125f32.to_bits());
    let models = ckpt.models();
    assert_eq!(models[0].hidden, vec![2]);
    assert_eq!(models[0].act, Act::Relu);
    assert_eq!(models[1].hidden, vec![3, 2]);
    assert_eq!(models[1].act, Act::Identity);
}

#[test]
fn golden_v3_predictions_are_bit_stable_under_every_kernel() {
    // The fixture's weights and inputs are small integers, every
    // intermediate is exactly representable, and FMA on exact values is
    // exact — so even the reassociating simd kernel must reproduce the
    // golden bits, not just approximate them.
    let ckpt = PoolCheckpoint::load(std::path::Path::new(GOLDEN_CKPT)).unwrap();
    let x = Tensor::from_vec(GOLDEN_X.to_vec(), &[4, 3]);
    for (m, want) in [(0usize, &GOLDEN_Y_M0), (1, &GOLDEN_Y_M1)] {
        let servable = ServableModel::from_checkpoint(&ckpt, m, format!("golden/m{m}")).unwrap();
        for kernel in [Kernel::Naive, Kernel::Blocked, Kernel::Simd] {
            let kcfg = KernelConfig::naive().with_kernel(kernel);
            for threads in [1usize, 4] {
                let got = servable.predict_with(kcfg, &x, threads);
                assert_bits(&got, &want[..], &format!("model {m} {kernel:?} t={threads}"));
            }
        }
    }
}

#[test]
fn golden_v3_winner_serves_bit_stable_through_the_microbatcher() {
    // same fixture, through the whole serving stack: registry winner
    // extraction + the coalescing worker (process-wide kernel)
    let ckpt = PoolCheckpoint::load(std::path::Path::new(GOLDEN_CKPT)).unwrap();
    let mut registry = ModelRegistry::new();
    registry.load_top_k("golden", &ckpt, 1).unwrap();
    let winner = registry.get("golden/top1").unwrap();
    assert_eq!(winner.index, 1);
    let server = Server::start(
        Arc::clone(&winner),
        ServeConfig { max_batch: 4, queue_cap: 16, threads: 1 },
    )
    .unwrap();
    let client = server.client();
    for (i, row) in GOLDEN_X.chunks(3).enumerate() {
        let got = client.predict(row).unwrap();
        for (j, (g, w)) in got.iter().zip(&GOLDEN_Y_M1[i * 2..(i + 1) * 2]).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "row {i} out {j}: {g} vs {w}");
        }
    }
    server.shutdown();
}

#[test]
fn noop_compaction_exports_byte_identical_checkpoints() {
    // compacting a pool where nothing was dropped must be invisible on
    // disk: same model table, same ranking, same parameter bytes
    let (spec, _layout, mut engine, x, y) = trained_engine(4);
    let keep: Vec<usize> = (0..spec.n_models()).collect();
    let compacted = engine.compact(&keep).unwrap();
    let (vl, vm) = engine.evaluate(&x, &y);
    let ranked = rank_models(&spec, &vl, &vm, Loss::Mse);
    let a = PoolCheckpoint::from_engine(&engine, Loss::Mse, &ranked).unwrap();
    let b = PoolCheckpoint::from_engine(&compacted, Loss::Mse, &ranked).unwrap();
    assert_eq!(
        a.to_bytes(),
        b.to_bytes(),
        "keep-everything compaction changed the exported checkpoint"
    );
}

#[test]
fn golden_v3_reassembles_byte_identically_from_dense_stacks() {
    // the halved-export path (from_dense_stacks over extracted/frozen
    // models) must write the exact same bytes the live-engine path does
    // — anchored to the committed fixture
    let bytes = std::fs::read(GOLDEN_CKPT).unwrap();
    let ckpt = PoolCheckpoint::from_bytes(&bytes).unwrap();
    let denses: Vec<_> =
        (0..ckpt.n_models()).map(|m| ckpt.stack().extract(&ckpt.params, m)).collect();
    let re =
        PoolCheckpoint::from_dense_stacks(denses, ckpt.loss, ckpt.ranking.clone()).unwrap();
    assert_eq!(re.to_bytes(), bytes, "dense-stack reassembly drifted from the v3 fixture");
}

#[test]
fn export_shape_survives_sequential_engine_too() {
    // from_engine goes through the PoolEngine trait, so the sequential
    // strategy checkpoints identically to the fused one
    use parallel_mlps::coordinator::SequentialEngine;
    use parallel_mlps::nn::optimizer::OptimizerKind;
    let spec = smoke_spec();
    let layout = PoolLayout::build(&spec);
    let fused = init_pool(7, &layout, F, O);
    let par = ParallelEngine::new(layout.clone(), fused.clone(), Loss::Mse, F, O, B, 1);
    let seq = SequentialEngine::from_pool(&spec, &layout, &fused, Loss::Mse, OptimizerKind::Sgd);
    let ck_par = PoolCheckpoint::from_engine(&par, Loss::Mse, &[]).unwrap();
    let ck_seq = PoolCheckpoint::from_engine(&seq, Loss::Mse, &[]).unwrap();
    assert!(stack_bits_equal(&ck_par.params, &ck_seq.params));
    // and both match the direct shallow wrap of the fused tensors
    let direct =
        PoolCheckpoint::from_shallow(&layout, F, O, Loss::Mse, &par.params_fused(), vec![])
            .unwrap();
    assert!(stack_bits_equal(&ck_par.params, &direct.params));
}
