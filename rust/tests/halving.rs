//! Successive-halving acceptance suite: the survivor bit-identity
//! contract, across thread counts and matmul kernels, for shallow and
//! mixed-depth pools — plus halved-session export under global ids.
//!
//! The contract under test: a model that survives rung cuts trains
//! through compacted (shrunk, repacked) fused pools, yet its parameter
//! trajectory must be BIT-identical to the same model trained in the
//! full uncompacted pool — at every thread count and under both
//! kernels. The reference runs the identical rung schedule (same
//! `TrainSession` sessions, same batches) on an uncompacted engine and
//! snapshots every model at each rung boundary; frozen (cut) models
//! must match their cut-rung snapshot, the winner its final snapshot.

use parallel_mlps::config::{ExperimentConfig, Strategy};
use parallel_mlps::coordinator::{run_halving, DeepEngine, PoolEngine, TrainSession};
use parallel_mlps::data::{random_regression, Dataset};
use parallel_mlps::io::{PoolCheckpoint, RankEntry};
use parallel_mlps::nn::act::Act;
use parallel_mlps::nn::init::init_pool;
use parallel_mlps::nn::loss::Loss;
use parallel_mlps::nn::parallel::ParallelEngine;
use parallel_mlps::nn::stack::{DenseStack, LayerStack, StackModel};
use parallel_mlps::pool::{PoolLayout, PoolSpec};
use parallel_mlps::selection::{halving_run, CompactableEngine, HalvingArm, HalvingConfig};
use parallel_mlps::tensor::kernels::Kernel;
use parallel_mlps::util::rng::Rng;

const F: usize = 4;
const O: usize = 2;
const BATCH: usize = 16;
const LR: f32 = 0.05;
const SEED: u64 = 11;

fn shallow_spec() -> PoolSpec {
    // 9 models: eta 3 halves 9 -> 3 -> 1
    PoolSpec::new(vec![
        (2, Act::Relu),
        (4, Act::Relu),
        (8, Act::Relu),
        (2, Act::Tanh),
        (4, Act::Tanh),
        (8, Act::Tanh),
        (2, Act::Sigmoid),
        (4, Act::Sigmoid),
        (3, Act::Gelu),
    ])
    .unwrap()
}

fn mixed_depth_models() -> Vec<StackModel> {
    // 9 models, depths 1, 2 and 3 coexisting in one pool
    let mut models = Vec::new();
    for &act in &[Act::Relu, Act::Tanh, Act::Sigmoid] {
        for depth in 1..=3usize {
            models.push(StackModel::uniform(2 + depth as u32, depth, act));
        }
    }
    models
}

fn shallow_engine(threads: usize, kernel: Kernel) -> ParallelEngine {
    let spec = shallow_spec();
    let layout = PoolLayout::build(&spec);
    let fused = init_pool(SEED, &layout, F, O);
    let mut engine = ParallelEngine::new(layout, fused, Loss::Mse, F, O, BATCH, threads);
    engine.set_kernel(kernel);
    engine
}

fn deep_engine(threads: usize, kernel: Kernel) -> DeepEngine {
    let stack = LayerStack::new(mixed_depth_models(), F, O).unwrap();
    let mut engine = DeepEngine::new(stack, SEED, Loss::Mse, threads);
    engine.set_kernel(kernel);
    engine
}

fn data() -> (Dataset, Dataset) {
    let mut rng = Rng::new(SEED ^ 0xDA7A);
    let ds = random_regression(96, F, O, &mut rng);
    let split = ds.split(0.75, 0.25, &mut rng);
    (split.train, split.val)
}

/// Train `engine` (uncompacted — every model keeps training) through the
/// same rung schedule and snapshot every model at each rung boundary.
fn reference_snapshots<E: PoolEngine + ?Sized>(
    engine: &mut E,
    train: &Dataset,
    rung_epochs: usize,
    n_rungs: usize,
) -> Vec<Vec<DenseStack>> {
    let mut snaps = Vec::with_capacity(n_rungs);
    for _ in 0..n_rungs {
        TrainSession::builder()
            .train_data(train)
            .batches(BATCH, false)
            .epochs(rung_epochs)
            .lr(LR)
            .run(engine)
            .unwrap();
        snaps.push(
            engine.extract_all().unwrap().into_iter().map(|e| e.into_stack()).collect(),
        );
    }
    snaps
}

/// Rung index at which each global model id was cut (final-rung
/// survivors map to the last rung).
fn cut_rung_of(report: &parallel_mlps::selection::HalvingReport) -> Vec<usize> {
    let mut cut_rung = vec![report.rungs.len() - 1; report.n_models];
    for (ri, rung) in report.rungs.iter().enumerate() {
        for &g in &rung.cut {
            cut_rung[g] = ri;
        }
    }
    cut_rung
}

/// The whole contract for one engine family: run halving under every
/// (threads, kernel) combination and compare every model — frozen and
/// live — against ONE reference (threads=1, naive, uncompacted).
fn assert_bit_identity<E, F2>(build: F2, n_models: usize)
where
    E: CompactableEngine,
    F2: Fn(usize, Kernel) -> E,
{
    let (train, val) = data();
    let cfg = HalvingConfig { eta: 3, rung_epochs: 2 };

    // reference: uncompacted, single-threaded, naive kernel
    let mut reference = build(1, Kernel::Naive);
    // schedule length for n -> n/3 -> ... -> 1
    let n_rungs = {
        let mut n = n_models;
        let mut rungs = 1;
        while n > 1 {
            n = (n / 3).max(1);
            rungs += 1;
        }
        rungs
    };
    let snaps = reference_snapshots(&mut reference, &train, cfg.rung_epochs, n_rungs);

    for threads in [1usize, 8] {
        for kernel in [Kernel::Naive, Kernel::Blocked] {
            let tag = format!("threads={threads} kernel={kernel:?}");
            let arm = HalvingArm {
                engine: build(threads, kernel),
                train: train.clone(),
                val: val.clone(),
            };
            let run = halving_run(vec![arm], BATCH, LR, Loss::Mse, &cfg, false).unwrap();
            assert_eq!(run.report.n_models, n_models, "{tag}");
            assert_eq!(run.report.rungs.len(), n_rungs, "{tag}");
            let pool = run.full_pool().unwrap();
            let cut_rung = cut_rung_of(&run.report);
            for g in 0..n_models {
                let want = &snaps[cut_rung[g]][g];
                assert!(
                    pool[g].bits_equal(want),
                    "{tag}: model {g} (cut at rung {}) diverged from the \
                     uncompacted reference trajectory",
                    cut_rung[g]
                );
            }
            // the final ranking covers the original pool exactly once
            let mut ids: Vec<usize> = run.report.ranked.iter().map(|r| r.index).collect();
            ids.sort_unstable();
            assert_eq!(ids, (0..n_models).collect::<Vec<_>>(), "{tag}");
        }
    }
}

#[test]
fn shallow_survivors_are_bit_identical_across_threads_and_kernels() {
    assert_bit_identity(shallow_engine, 9);
}

#[test]
fn mixed_depth_survivors_are_bit_identical_across_threads_and_kernels() {
    assert_bit_identity(deep_engine, 9);
}

#[test]
fn rung_cuts_are_identical_across_threads_and_kernels() {
    // not just the weights: the SCHEDULE (who got cut when) must agree
    let (train, val) = data();
    let cfg = HalvingConfig { eta: 3, rung_epochs: 2 };
    let mut schedules: Vec<Vec<Vec<usize>>> = Vec::new();
    for threads in [1usize, 8] {
        for kernel in [Kernel::Naive, Kernel::Blocked] {
            let arm = HalvingArm {
                engine: shallow_engine(threads, kernel),
                train: train.clone(),
                val: val.clone(),
            };
            let run = halving_run(vec![arm], BATCH, LR, Loss::Mse, &cfg, false).unwrap();
            schedules.push(run.report.rungs.iter().map(|r| r.cut.clone()).collect());
        }
    }
    for s in &schedules[1..] {
        assert_eq!(s, &schedules[0]);
    }
}

fn halving_cfg_for(strategy: Strategy) -> ExperimentConfig {
    ExperimentConfig {
        strategy,
        samples: 120,
        features: 5,
        out: 2,
        hidden_sizes: vec![2, 4, 8],
        acts: vec![Act::Relu, Act::Tanh, Act::Sigmoid],
        repeats: 1,
        epochs: 6,
        batch: 16,
        lr: 0.05,
        loss: Loss::Mse,
        threads: 2,
        seed: 21,
        ..Default::default()
    }
}

#[test]
fn halved_export_checkpoints_the_whole_pool_under_global_ids() {
    let cfg = halving_cfg_for(Strategy::NativeParallel);
    let hcfg = HalvingConfig { eta: 3, rung_epochs: 1 };
    let halved = run_halving(&cfg, &hcfg).unwrap();
    assert_eq!(halved.models.len(), 9);

    let ranking: Vec<RankEntry> = halved
        .report
        .ranked
        .iter()
        .map(|r| RankEntry { index: r.index, val_loss: r.val_loss, val_metric: r.val_metric })
        .collect();
    let ckpt =
        PoolCheckpoint::from_dense_stacks(halved.models.clone(), cfg.loss, ranking).unwrap();

    // checkpoint slot g is ORIGINAL pool model g, bit for bit — cut
    // models included
    assert_eq!(ckpt.n_models(), 9);
    let spec = cfg.pool_spec().unwrap();
    for g in 0..9 {
        let stored = ckpt.stack().extract(&ckpt.params, g);
        assert!(stored.bits_equal(&halved.models[g]), "model {g}");
        assert_eq!(stored.hidden() as u32, spec.models()[g].0, "model {g}");
        assert_eq!(stored.act, spec.models()[g].1, "model {g}");
    }
    // the persisted ranking is the halving report's global ranking, and
    // the winner is the sole final-rung survivor
    assert_eq!(ckpt.winner(), Some(halved.report.ranked[0].index));
    let last = halved.report.rungs.last().unwrap();
    assert_eq!(last.survivors, vec![halved.report.ranked[0].index]);
    for (e, r) in ckpt.ranking.iter().zip(&halved.report.ranked) {
        assert_eq!(e.index, r.index);
        assert_eq!(e.val_loss.to_bits(), r.val_loss.to_bits());
    }
    // and the file round-trips like any other v3 checkpoint
    let bytes = ckpt.to_bytes();
    let back = PoolCheckpoint::from_bytes(&bytes).unwrap();
    assert_eq!(back.to_bytes(), bytes);
}

#[test]
fn halved_export_mixed_depths_keeps_each_models_architecture() {
    let mut cfg = halving_cfg_for(Strategy::DeepNative);
    cfg.hidden_sizes = vec![3, 4, 5];
    cfg.acts = vec![Act::Relu];
    cfg.depths = Some(vec![1, 2, 3]);
    let hcfg = HalvingConfig { eta: 3, rung_epochs: 1 };
    let halved = run_halving(&cfg, &hcfg).unwrap();
    assert_eq!(halved.models.len(), 9);
    let ranking: Vec<RankEntry> = halved
        .report
        .ranked
        .iter()
        .map(|r| RankEntry { index: r.index, val_loss: r.val_loss, val_metric: r.val_metric })
        .collect();
    let ckpt =
        PoolCheckpoint::from_dense_stacks(halved.models.clone(), cfg.loss, ranking).unwrap();
    let models = cfg.stack_models().unwrap();
    for g in 0..9 {
        let stored = ckpt.stack().extract(&ckpt.params, g);
        assert_eq!(stored.hidden_widths(), models[g].hidden, "model {g}");
        assert!(stored.bits_equal(&halved.models[g]), "model {g}");
    }
    // depths 1..3 all survived into the checkpoint
    let mut depths: Vec<usize> =
        (0..9).map(|g| ckpt.stack().extract(&ckpt.params, g).n_hidden_layers()).collect();
    depths.sort_unstable();
    depths.dedup();
    assert_eq!(depths, vec![1, 2, 3]);
}

#[test]
fn run_halving_is_thread_count_invariant() {
    // the coordinator path (resolve/prepare/build) inherits the
    // scheduler's guarantee: changing only the thread count changes
    // nothing in the result
    let mut a_cfg = halving_cfg_for(Strategy::NativeParallel);
    let mut b_cfg = a_cfg.clone();
    a_cfg.threads = 1;
    b_cfg.threads = 8;
    let hcfg = HalvingConfig { eta: 3, rung_epochs: 2 };
    let a = run_halving(&a_cfg, &hcfg).unwrap();
    let b = run_halving(&b_cfg, &hcfg).unwrap();
    for (g, (ma, mb)) in a.models.iter().zip(&b.models).enumerate() {
        assert!(ma.bits_equal(mb), "model {g} differs between 1 and 8 threads");
    }
    let oa: Vec<usize> = a.report.ranked.iter().map(|r| r.index).collect();
    let ob: Vec<usize> = b.report.ranked.iter().map(|r| r.index).collect();
    assert_eq!(oa, ob);
}
