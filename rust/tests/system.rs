//! System-level integration: manifests, sweeps, configs, selection and
//! failure injection (corrupted manifests/pools must be rejected loudly).

use std::path::Path;

use parallel_mlps::config::{ExperimentConfig, Strategy};
use parallel_mlps::coordinator::{render_paper_table, run_experiment, run_table, SweepConfig, TableKind};
use parallel_mlps::data::SynthKind;
use parallel_mlps::nn::act::Act;
use parallel_mlps::nn::loss::Loss;
use parallel_mlps::pool::PoolSpec;
use parallel_mlps::runtime::{Manifest, PjrtRuntime};

fn artifacts_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn pjrt_quick_sweep_produces_paper_shape() {
    // A miniature Table 2: parallel must beat sequential by a wide margin
    // on the dispatch-bound PJRT device.
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let mut cfg = SweepConfig::quick(SweepConfig::bench_pool());
    cfg.features = vec![5];
    cfg.samples = vec![100];
    let cells = run_table(TableKind::Pjrt, &cfg, Some(&artifacts_dir())).unwrap();
    assert_eq!(cells.len(), 1);
    let c = &cells[0];
    assert!(
        c.ratio() < 0.5,
        "parallel should be far faster than sequential on pjrt: ratio {}",
        c.ratio()
    );
    let md = render_paper_table("mini", &cfg, &cells);
    assert!(md.contains("Parallel/Sequential"));
}

#[test]
fn native_quick_sweep_parallel_wins() {
    let mut cfg = SweepConfig::quick(SweepConfig::bench_pool());
    cfg.features = vec![10];
    cfg.samples = vec![200];
    cfg.epochs = 3;
    cfg.warmup = 1;
    let cells = run_table(TableKind::NativeCpu, &cfg, None).unwrap();
    let c = &cells[0];
    assert!(
        c.ratio() < 1.0,
        "fused native should beat sequential native: ratio {}",
        c.ratio()
    );
}

#[test]
fn corrupted_manifest_checksum_is_rejected() {
    // failure injection: flip the recorded checksum and expect validation
    // to refuse (this is the guard against layout-compiler divergence).
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let tmp = std::env::temp_dir().join(format!("pmlp_corrupt_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    // flip the first hex digit of the first checksum (keeping length 16)
    let key = "\"checksum\": \"";
    let pos = text.find(key).unwrap() + key.len();
    let old = text.as_bytes()[pos] as char;
    let new = if old == '0' { '1' } else { '0' };
    let mut corrupted = text.clone();
    corrupted.replace_range(pos..pos + 1, &new.to_string());
    assert_ne!(text, corrupted);
    std::fs::write(tmp.join("manifest.json"), corrupted).unwrap();
    // artifact files referenced must exist for validate(); copy the HLOs
    for entry in std::fs::read_dir(&dir).unwrap() {
        let p = entry.unwrap().path();
        if p.extension().map(|e| e == "txt").unwrap_or(false) {
            std::fs::copy(&p, tmp.join(p.file_name().unwrap())).unwrap();
        }
    }
    let m = Manifest::load(&tmp).unwrap();
    let err = m.validate().unwrap_err().to_string();
    assert!(err.contains("checksum mismatch"), "{err}");
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn missing_artifact_file_is_rejected() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let tmp = std::env::temp_dir().join(format!("pmlp_missing_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    std::fs::copy(dir.join("manifest.json"), tmp.join("manifest.json")).unwrap();
    // no HLO files copied -> every artifact is missing
    let m = Manifest::load(&tmp).unwrap();
    let err = m.validate().unwrap_err().to_string();
    assert!(err.contains("missing"), "{err}");
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn runtime_rejects_unknown_pool_and_artifact() {
    if !artifacts_dir().join("manifest.json").exists() {
        return;
    }
    let rt = PjrtRuntime::new(&artifacts_dir()).unwrap();
    assert!(rt.manifest.layout("not_a_pool").is_err());
    assert!(rt.executable("not_an_artifact").is_err());
}

#[test]
fn config_driven_experiment_selects_sensible_model() {
    // blobs are easy: after a few epochs the best model should have high
    // accuracy, and selection must return it first.
    let cfg = ExperimentConfig {
        name: "it_blobs".into(),
        dataset: SynthKind::Blobs,
        samples: 300,
        features: 8,
        out: 3,
        hidden_sizes: vec![1, 4, 8],
        acts: vec![Act::Relu, Act::Tanh],
        repeats: 1,
        epochs: 15,
        warmup_epochs: 2,
        batch: 30,
        lr: 0.2,
        loss: Loss::Ce,
        strategy: Strategy::NativeParallel,
        threads: 2,
        seed: 3,
        ..Default::default()
    };
    let rep = run_experiment(&cfg).unwrap();
    assert_eq!(rep.ranked.len(), 6);
    assert!(
        rep.ranked[0].val_metric > 0.8,
        "best model should classify blobs: {:?}",
        rep.ranked[0]
    );
    // larger-hidden models should generally beat h=1 on 3-class blobs
    assert!(rep.ranked[0].hidden >= 4, "{:?}", rep.ranked);
}

#[test]
fn sequential_strategy_produces_same_ranking_losses() {
    let base = ExperimentConfig {
        dataset: SynthKind::TeacherMlp,
        samples: 120,
        features: 5,
        out: 2,
        teacher_hidden: 4,
        hidden_sizes: vec![2, 4],
        acts: vec![Act::Tanh],
        epochs: 6,
        warmup_epochs: 1,
        batch: 20,
        lr: 0.05,
        loss: Loss::Mse,
        threads: 2,
        seed: 11,
        ..Default::default()
    };
    let par = run_experiment(&base).unwrap();
    let seq = run_experiment(&ExperimentConfig {
        strategy: Strategy::NativeSequential,
        ..base
    })
    .unwrap();
    let vp = par.outcome.val_losses.unwrap();
    let vs = seq.outcome.val_losses.unwrap();
    for (a, b) in vp.iter().zip(&vs) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
    // and the ranking order matches
    let op: Vec<usize> = par.ranked.iter().map(|r| r.index).collect();
    let os: Vec<usize> = seq.ranked.iter().map(|r| r.index).collect();
    assert_eq!(op, os);
}

#[test]
fn paper_full_pool_layout_scales() {
    // the 10,000-model pool compiles a layout quickly and passes checks
    let spec = PoolSpec::paper_full();
    let lay = parallel_mlps::pool::PoolLayout::build(&spec);
    assert_eq!(lay.n_models(), 10_000);
    assert!(lay.padding_efficiency() > 0.5, "{}", lay.padding_efficiency());
    // §5 memory note: fused params at F=100 fit easily in host RAM
    assert!(lay.fused_param_bytes(100, 2) < 1 << 30);
}
