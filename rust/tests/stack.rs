//! The arbitrary-depth vertical, end to end: a heterogeneous-depth pool
//! trains through `TrainSession`, exports to a PMLPCKPT v2 file, and its
//! winners serve through `ModelRegistry` with logits matching the fused
//! pool — while legacy v1 checkpoints keep loading and serving.

use parallel_mlps::config::{ExperimentConfig, Strategy};
use parallel_mlps::coordinator::{run_experiment_trained, DeepEngine, PoolEngine, TrainSession};
use parallel_mlps::data;
use parallel_mlps::io::{to_v1_bytes, PoolCheckpoint, RankEntry};
use parallel_mlps::nn::act::Act;
use parallel_mlps::nn::init::init_pool;
use parallel_mlps::nn::loss::Loss;
use parallel_mlps::nn::stack::{stack_bits_equal, LayerStack, StackModel};
use parallel_mlps::pool::{extract_model, PoolLayout, PoolSpec};
use parallel_mlps::selection::rank_models;
use parallel_mlps::serve::{ModelRegistry, ServableModel};
use parallel_mlps::util::rng::Rng;

const F: usize = 5;
const O: usize = 2;

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("pmlp_stack_test_{tag}_{}.ckpt", std::process::id()))
}

/// Depths 1, 2 and 3 fused in one pool.
fn mixed_stack() -> LayerStack {
    LayerStack::new(
        vec![
            StackModel { hidden: vec![4], act: Act::Sigmoid },
            StackModel { hidden: vec![3, 2], act: Act::Tanh },
            StackModel { hidden: vec![2, 3, 2], act: Act::Relu },
            StackModel { hidden: vec![4, 4, 4], act: Act::Gelu },
        ],
        F,
        O,
    )
    .unwrap()
}

/// THE acceptance path: depth-3 heterogeneous pool -> TrainSession ->
/// PMLPCKPT v2 file -> ModelRegistry -> served logits match the fused
/// pool's per-model logits within 1e-5.
#[test]
fn depth3_pool_trains_exports_and_serves() {
    let mut engine = DeepEngine::new(mixed_stack(), 23, Loss::Mse, 2);
    let mut rng = Rng::new(6);
    let ds = data::random_regression(64, F, O, &mut rng);
    let rep = TrainSession::builder()
        .train_data(&ds)
        .batches(16, false)
        .epochs(4)
        .lr(0.05)
        .run(&mut engine)
        .unwrap();
    assert_eq!(rep.outcome.final_losses.len(), 4);

    // rank on a quick eval so the checkpoint carries a real ranking
    let (x, y) = ds.batch(0, 16);
    let (vl, vm) = engine.eval(0, &x, &y).unwrap();
    let spec = parallel_mlps::coordinator::stack_ranking_spec(engine.stack()).unwrap();
    let ranked = rank_models(&spec, &vl, &vm, Loss::Mse);

    // export -> file -> reload, bit-exact
    let ckpt = PoolCheckpoint::from_engine(&engine, Loss::Mse, &ranked).unwrap();
    assert_eq!(ckpt.depth(), 3);
    let path = tmp("depth3");
    ckpt.save(&path).unwrap();
    let back = PoolCheckpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(stack_bits_equal(&ckpt.params, &back.params));

    // serve every model; logits must match the fused pool per model
    let fused_logits = engine.stack().forward(engine.params(), &x, 2);
    let mut registry = ModelRegistry::new();
    let names = registry.load_top_k("pool", &back, 4).unwrap();
    assert_eq!(names.len(), 4);
    for (rank, name) in names.iter().enumerate() {
        let servable = registry.get(name).unwrap();
        let m = servable.index;
        assert_eq!(m, ranked[rank].index);
        let pred = servable.predict(&x, 1);
        for bi in 0..x.rows() {
            for oi in 0..O {
                let fused = fused_logits.at3(bi, m, oi);
                let served = pred.at2(bi, oi);
                assert!(
                    (fused - served).abs() < 1e-5,
                    "model {m} row {bi} out {oi}: fused {fused} vs served {served}"
                );
            }
        }
    }
    // the winner really carries its validation stats
    let top1 = registry.get("pool/top1").unwrap();
    assert!((top1.val_loss - ranked[0].val_loss).abs() < 1e-6);
}

/// The config-driven path: `pmlp train --strategy deep_native --depths
/// 2,3` trains mixed-depth stacks through the one generic loop.
#[test]
fn run_experiment_handles_mixed_depths() {
    let cfg = ExperimentConfig {
        strategy: Strategy::DeepNative,
        dataset: data::SynthKind::Blobs,
        samples: 160,
        features: 6,
        out: 2,
        hidden_sizes: vec![2, 4],
        acts: vec![Act::Relu],
        depths: Some(vec![2, 3]),
        epochs: 3,
        warmup_epochs: 1,
        batch: 20,
        lr: 0.1,
        loss: Loss::Ce,
        threads: 2,
        seed: 9,
        ..Default::default()
    };
    let trained = run_experiment_trained(&cfg).unwrap();
    // 2 hidden sizes x 1 act x 2 depths = 4 models
    assert_eq!(trained.report.ranked.len(), 4);
    assert!(trained
        .report
        .outcome
        .val_losses
        .as_ref()
        .unwrap()
        .iter()
        .all(|v| v.is_finite()));
    // the trained engine checkpoints straight through the trait
    let ckpt =
        PoolCheckpoint::from_engine(trained.engine.as_ref(), cfg.loss, &trained.report.ranked)
            .unwrap();
    assert_eq!(ckpt.depth(), 3);
    assert_eq!(ckpt.n_models(), 4);
    let depths: Vec<usize> = ckpt.models().iter().map(|m| m.depth()).collect();
    assert_eq!(depths, vec![2, 3, 2, 3]);
}

/// Legacy compatibility: a v1 (shallow, padded-layout) checkpoint file
/// still loads — as a depth-1 stack — and serves unchanged.
#[test]
fn v1_checkpoint_loads_and_serves_unchanged() {
    let spec = PoolSpec::new(vec![(2, Act::Relu), (3, Act::Tanh), (1, Act::Identity)]).unwrap();
    let layout = PoolLayout::build(&spec);
    let fused = init_pool(41, &layout, F, O);
    let ranking = vec![
        RankEntry { index: 1, val_loss: 0.2, val_metric: 0.2 },
        RankEntry { index: 0, val_loss: 0.4, val_metric: 0.4 },
    ];
    let bytes = to_v1_bytes(&layout, F, O, Loss::Mse, &fused, &ranking);
    let path = tmp("v1");
    std::fs::write(&path, &bytes).unwrap();
    let ckpt = PoolCheckpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(ckpt.depth(), 1);
    assert_eq!(ckpt.winner(), Some(1));

    let mut rng = Rng::new(8);
    let mut x = parallel_mlps::tensor::Tensor::zeros(&[6, F]);
    rng.fill_normal(x.data_mut(), 0.0, 1.0);
    let mut registry = ModelRegistry::new();
    registry.load_top_k("legacy", &ckpt, 2).unwrap();
    let top1 = registry.get("legacy/top1").unwrap();
    assert_eq!(top1.index, 1);
    // served logits == the historical dense forward of the sliced model
    let (dense, act) = extract_model(&fused, &layout, 1);
    let want = dense.forward(&x, act, 1);
    let got = top1.predict(&x, 1);
    assert!(got
        .data()
        .iter()
        .zip(want.data())
        .all(|(a, b)| a.to_bits() == b.to_bits()));
}

/// Format evolution hygiene: truncated or corrupted v2 files fail with
/// an error (never a panic), and a depth-3 roundtrip is bit-exact even
/// with non-finite parameters.
#[test]
fn corrupted_and_truncated_v2_fail_cleanly() {
    let stack = mixed_stack();
    let mut params = stack.init(3);
    params.layers[1].w.data_mut()[0] = f32::NAN; // diverged model survives
    let ckpt = PoolCheckpoint::new(stack, Loss::Mse, params, vec![]).unwrap();
    let bytes = ckpt.to_bytes();

    // bit-exact roundtrip, NaN included
    let back = PoolCheckpoint::from_bytes(&bytes).unwrap();
    assert!(stack_bits_equal(&ckpt.params, &back.params));

    // every truncation point fails cleanly
    for cut in [0, 7, 8, 11, bytes.len() / 4, bytes.len() / 2, bytes.len() - 1] {
        assert!(
            PoolCheckpoint::from_bytes(&bytes[..cut]).is_err(),
            "truncation at {cut} accepted"
        );
    }
    // every flipped byte fails cleanly
    for pos in [9, 20, bytes.len() / 3, bytes.len() - 2] {
        let mut bad = bytes.clone();
        bad[pos] ^= 0x10;
        assert!(PoolCheckpoint::from_bytes(&bad).is_err(), "flip at {pos} accepted");
    }
}

/// Depth through the whole engine API: extraction of a served winner and
/// the engine's own eval agree, so ranking signals mean the same thing
/// for deep pools as for shallow ones.
#[test]
fn deep_eval_matches_served_winner_loss() {
    let stack = mixed_stack();
    let mut engine = DeepEngine::new(stack, 15, Loss::Mse, 1);
    let mut rng = Rng::new(12);
    let ds = data::random_regression(32, F, O, &mut rng);
    let (x, y) = ds.batch(0, 32);
    for _ in 0..5 {
        engine.step(0, 0, &x, &y, 0.05).unwrap();
    }
    let (losses, _) = engine.eval(0, &x, &y).unwrap();
    for m in 0..engine.n_models() {
        let dense = engine.extract(m).unwrap().stacked().unwrap();
        let servable = ServableModel::new(format!("m{m}"), m, dense);
        let pred = servable.predict(&x, 1);
        let lv = parallel_mlps::nn::loss::mlp_loss(Loss::Mse, &pred, &y);
        assert!(
            (lv - losses[m]).abs() < 1e-5,
            "model {m}: served loss {lv} vs engine eval {}",
            losses[m]
        );
    }
}
