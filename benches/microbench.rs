//! Microbenchmarks over the native substrate: matmul kernels, M3 stage
//! costs, activation throughput, scatter-add — the per-op numbers that
//! explain (or refute) the end-to-end tables.
//!
//! Run: `cargo bench --bench microbench [-- --quick]`

use parallel_mlps::bench_harness::{measure, BenchArgs, Measurement};
use parallel_mlps::data;
use parallel_mlps::metrics::Timer;
use parallel_mlps::nn::act::ALL_ACTS;
use parallel_mlps::nn::init::{extract_model, init_pool};
use parallel_mlps::nn::loss::Loss;
use parallel_mlps::nn::mlp::MlpTrainer;
use parallel_mlps::nn::optimizer::OptimizerKind;
use parallel_mlps::nn::parallel::ParallelEngine;
use parallel_mlps::pool::{PoolLayout, PoolSpec};
use parallel_mlps::tensor::kernels::{self, Kernel, KernelConfig};
use parallel_mlps::tensor::{matmul, scatter, Tensor};
use parallel_mlps::util::rng::Rng;

/// Loose relative-tolerance smoke check for the reassociating simd
/// kernel: bit equality is the wrong assert (FMA legitimately moves
/// low-order bits), and this is deliberately NOT the acceptance bound —
/// the strict `16·(k+2)·eps·S` magnitude-oracle / 64-ulp gate lives in
/// `rust/tests/kernels.rs`. Here the fixed 1e-4 tolerance only guards
/// against timing a wrong kernel (wrong element, dropped k-slice —
/// misses by orders of magnitude, not ulps).
fn assert_rel_close(got: &[f32], want: &[f32], tag: &str) {
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let tol = 1e-4 * (1.0 + w.abs());
        assert!(
            (g - w).abs() <= tol,
            "simd kernel disagreement on {tag}[{i}]: {g} vs {w}"
        );
    }
}

fn main() {
    let args = BenchArgs::from_env();
    let reps = if args.quick { 3 } else { 10 };
    let mut rng = Rng::new(1);
    // (measurement, flop count per rep) — flops turn the ms column into
    // a GFLOP/s column so speedups compare across shapes
    let mut results: Vec<(Measurement, Option<f64>)> = Vec::new();

    // --- naive vs blocked vs simd kernel on the fused training shapes ------
    // the [B,F]x[F,H_pad] projections and the [H_pad,B,F]-class weight
    // grads are exactly what `pmlp train-bench` exercises; the blocked
    // kernel must beat the naive oracle here (ISSUE 5 acceptance) and
    // simd must beat blocked on AVX2 hosts (ISSUE 8 acceptance)
    eprintln!("active kernel: {}", kernels::active().describe());
    let mut kernel_axis = vec![Kernel::Naive, Kernel::Blocked];
    if kernels::simd_available() {
        kernel_axis.push(Kernel::Simd);
    } else {
        eprintln!("simd kernel column: skipped (this host lacks AVX2+FMA)");
    }
    for &(m, k, n, tag) in &[
        (32usize, 16usize, 2560usize, "fwd fused [B,F]x[F,H_pad]"),
        (256, 64, 1024, "fwd fused big [B,F]x[F,H_pad]"),
    ] {
        let mut a = Tensor::zeros(&[m, k]);
        rng.fill_normal(a.data_mut(), 0.0, 1.0);
        let mut b = Tensor::zeros(&[k, n]);
        rng.fill_normal(b.data_mut(), 0.0, 1.0);
        let mut c = Tensor::zeros(&[m, n]);
        // sanity: the tier-1 kernels must agree bit-for-bit before
        // timing; simd within the relative smoke tolerance
        let mut c2 = Tensor::zeros(&[m, n]);
        kernels::matmul_nn_with(KernelConfig::naive(), a.data(), b.data(), c.data_mut(), m, k, n, 1)
            .unwrap();
        kernels::matmul_nn_with(KernelConfig::blocked(), a.data(), b.data(), c2.data_mut(), m, k, n, 1)
            .unwrap();
        assert!(
            c.data().iter().zip(c2.data()).all(|(x, y)| x.to_bits() == y.to_bits()),
            "kernel disagreement on {tag}"
        );
        if kernels::simd_available() {
            kernels::matmul_nn_with(
                KernelConfig::simd(),
                a.data(),
                b.data(),
                c2.data_mut(),
                m,
                k,
                n,
                1,
            )
            .unwrap();
            assert_rel_close(c2.data(), c.data(), tag);
        }
        for &kernel in &kernel_axis {
            // time the autotuned tiles the `auto` default actually ships
            // (the header line above describes exactly this config)
            let cfg = kernels::active().with_kernel(kernel);
            results.push((
                measure(
                    &format!("matmul_nn {:<7} {tag} [{m}x{k}x{n}]", kernel.name()),
                    2,
                    reps,
                    || {
                        kernels::matmul_nn_with(cfg, a.data(), b.data(), c.data_mut(), m, k, n, 1)
                            .unwrap();
                        std::hint::black_box(c.data()[0]);
                    },
                ),
                Some(2.0 * m as f64 * k as f64 * n as f64),
            ));
        }
    }
    {
        // dW1-class tn shape: [F,B]ᵀ x [B,H_pad]
        let (m, k, n) = (64usize, 256usize, 1024usize);
        let mut a = Tensor::zeros(&[k, m]);
        rng.fill_normal(a.data_mut(), 0.0, 1.0);
        let mut b = Tensor::zeros(&[k, n]);
        rng.fill_normal(b.data_mut(), 0.0, 1.0);
        let mut c = Tensor::zeros(&[m, n]);
        if kernels::simd_available() {
            let mut want = Tensor::zeros(&[m, n]);
            kernels::matmul_tn_with(
                KernelConfig::naive(),
                a.data(),
                b.data(),
                want.data_mut(),
                m,
                k,
                n,
                1,
            )
            .unwrap();
            kernels::matmul_tn_with(
                KernelConfig::simd(),
                a.data(),
                b.data(),
                c.data_mut(),
                m,
                k,
                n,
                1,
            )
            .unwrap();
            assert_rel_close(c.data(), want.data(), "dW1 fused tn");
        }
        for &kernel in &kernel_axis {
            let cfg = kernels::active().with_kernel(kernel);
            results.push((
                measure(
                    &format!("matmul_tn {:<7} dW1 fused [{m}x{k}x{n}]", kernel.name()),
                    2,
                    reps,
                    || {
                        kernels::matmul_tn_with(cfg, a.data(), b.data(), c.data_mut(), m, k, n, 1)
                            .unwrap();
                        std::hint::black_box(c.data()[0]);
                    },
                ),
                Some(2.0 * m as f64 * k as f64 * n as f64),
            ));
        }
    }

    // --- matmul kernels at MLP-relevant shapes -----------------------------
    for &(m, k, n, tag) in &[
        (32usize, 10usize, 2560usize, "fwd fused (B x F x H_pad)"),
        (32, 10, 11, "fwd one model (B x F x h)"),
        (2560, 32, 10, "dW1 fused (H_pad x B x F)"),
    ] {
        let mut a = Tensor::zeros(&[m, k]);
        rng.fill_normal(a.data_mut(), 0.0, 1.0);
        let mut b = Tensor::zeros(&[n, k]);
        rng.fill_normal(b.data_mut(), 0.0, 1.0);
        results.push((
            measure(&format!("matmul_nt {tag} [{m}x{k}x{n}]"), 2, reps, || {
                let c = matmul::nt(&a, &b, 1);
                std::hint::black_box(c.data()[0]);
            }),
            Some(2.0 * m as f64 * k as f64 * n as f64),
        ));
    }

    // --- activation throughput (71k elements, per function) ---------------
    let mut xs = vec![0.0f32; 71_680];
    rng.fill_normal(&mut xs, 0.0, 1.0);
    let mut out = vec![0.0f32; xs.len()];
    for act in ALL_ACTS {
        results.push((
            measure(&format!("act {:<11} 71k elems", act.name()), 1, reps, || {
                act.apply_slice(&xs, &mut out);
                std::hint::black_box(out[0]);
            }),
            None,
        ));
    }

    // --- scatter-add: paper semantics vs contiguous segment sum -----------
    let src = Tensor::from_vec(xs[..32 * 2200].to_vec(), &[32, 2200]);
    let spec = PoolSpec::from_grid(&[2, 4, 8, 16, 25], &ALL_ACTS, 4).unwrap();
    let lay = PoolLayout::build(&spec);
    let mut index = vec![0u32; 32 * 2200];
    let mut spans = Vec::new();
    {
        let mut col = 0usize;
        for m in 0..lay.n_models() {
            let h = lay.spec().models()[m].0 as usize;
            spans.push((col, col + h));
            for r in 0..32 {
                for c in col..col + h {
                    index[r * 2200 + c] = lay.slot[m] as u32;
                }
            }
            col += h;
        }
    }
    results.push((
        measure("scatter_add_dim1 (indexed, paper form)", 1, reps, || {
            let r = scatter::scatter_add_dim1(&src, &index, lay.m_pad());
            std::hint::black_box(r.data()[0]);
        }),
        None,
    ));
    results.push((
        measure("segment_sum (contiguous, fused layout)", 1, reps, || {
            let mut o = vec![0.0f32; spans.len()];
            for row in 0..32 {
                scatter::segment_sum_contiguous(
                    &src.data()[row * 2200..(row + 1) * 2200],
                    &spans,
                    &mut o,
                );
            }
            std::hint::black_box(o[0]);
        }),
        None,
    ));

    // --- fused step vs sequential steps, end to end -------------------------
    let f = 10;
    let o = 2;
    let b = 32;
    let fused = init_pool(7, &lay, f, o);
    let mut engine = ParallelEngine::new(lay.clone(), fused.clone(), Loss::Mse, f, o, b, 1);
    let ds = data::random_regression(b, f, o, &mut rng);
    let (x, y) = ds.batch(0, b);
    results.push((
        measure("fused step (200 models, 1 batch)", 2, reps, || {
            std::hint::black_box(engine.step(&x, &y, 0.01).len());
        }),
        None,
    ));
    let mut trainers: Vec<MlpTrainer> = (0..spec.n_models())
        .map(|m| {
            MlpTrainer::new(
                extract_model(&fused, &lay, m),
                spec.models()[m].1,
                Loss::Mse,
                OptimizerKind::Sgd,
                1,
            )
        })
        .collect();
    results.push((
        measure("sequential steps (200 models, 1 batch)", 2, reps, || {
            for t in trainers.iter_mut() {
                std::hint::black_box(t.step(&x, &y, 0.01));
            }
        }),
        None,
    ));

    // --- dataset batch slicing (the per-batch training hot path) -----------
    // one full epoch of contiguous batch() calls; the contiguous-copy
    // implementation must agree bit-for-bit with the take() reference
    let big = data::random_regression(4096, 32, 4, &mut rng);
    {
        let (fast, _) = big.batch(640, 64);
        let idx: Vec<usize> = (640..704).collect();
        let slow = big.take(&idx);
        assert!(
            fast.data().iter().zip(slow.x.data()).all(|(a, b)| a.to_bits() == b.to_bits()),
            "batch() diverged from the take() reference"
        );
    }
    results.push((
        measure("dataset batch x64 (4096 rows, epoch of slices)", 2, reps, || {
            let mut acc = 0f32;
            let mut start = 0;
            while start < big.len() {
                let (x, y) = big.batch(start, 64);
                acc += x.data()[0] + y.data()[0];
                start += x.rows();
            }
            std::hint::black_box(acc);
        }),
        None,
    ));

    // --- report -------------------------------------------------------------
    let t = Timer::new();
    let mut report = String::from("## microbench\n\n```\n");
    for (r, flops) in &results {
        report.push_str(&r.summary());
        match flops {
            Some(fl) if r.stats.min() > 0.0 => {
                report.push_str(&format!("  {:>8.2} GFLOP/s", fl / r.stats.min() / 1e9));
            }
            _ => {}
        }
        report.push('\n');
    }
    report.push_str("```\n");
    args.emit(&report);
    eprintln!("(reporting took {:.2}s)", t.elapsed_s());
}
