//! Micro-batched serving bench — the inference-side perf table: rows/s
//! and p50/p99 latency for max_batch 1/8/64 on a synthetic winner.
//!
//! ```sh
//! cargo bench --bench serve_bench -- --quick
//! cargo bench --bench serve_bench -- --out BENCH_serve.json
//! ```

use parallel_mlps::bench_harness::BenchArgs;
use parallel_mlps::serve::bench::{render_reports, reports_json, run_load, synthetic_model, LoadSpec};
use parallel_mlps::serve::ServeConfig;

fn main() {
    let bargs = BenchArgs::from_env();
    let (rows_per_client, clients, depth, hidden) =
        if bargs.quick { (128, 2, 8, 64) } else { (1024, 4, 16, 256) };
    let model = synthetic_model(hidden, 64, 8, 42);
    let spec = LoadSpec { rows_per_client, clients, depth, seed: 42 };
    let mut reports = Vec::new();
    for max_batch in [1usize, 8, 64] {
        let cfg = ServeConfig { max_batch, queue_cap: 4096, threads: 1 };
        match run_load(&model, cfg, &spec) {
            Ok(r) => {
                eprintln!(
                    "max_batch {max_batch}: {:.0} rows/s (p50 {:.3} ms, p99 {:.3} ms)",
                    r.rows_per_s, r.p50_ms, r.p99_ms
                );
                reports.push(r);
            }
            Err(e) => {
                eprintln!("serve bench failed: {e}");
                std::process::exit(1);
            }
        }
    }
    println!(
        "{}",
        render_reports("serve: micro-batched vs per-row dispatch", &reports)
    );
    // --out writes the JSON record (BENCH_serve.json), not the markdown
    if let Some(path) = &bargs.out_path {
        match std::fs::write(path, reports_json(&model, &spec, &reports)) {
            Ok(()) => eprintln!("json written to {path}"),
            Err(e) => eprintln!("writing {path}: {e}"),
        }
    }
}
