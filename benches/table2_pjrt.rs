//! Table 2 reproduction — the accelerator analog: XLA CPU PJRT device.
//!
//! Parallel = ONE fused AOT artifact execution per batch (the Pallas M3
//! train step); Sequential = one tiny artifact execution per model per
//! batch. Per-execute dispatch overhead plays the role of CUDA kernel
//! launch cost, reproducing the paper's GPU-side gap (0.017%–0.486%).
//!
//! Run:  cargo bench --bench table2_pjrt [-- --quick]
//! Requires artifacts (`make artifacts`); pool is the manifest's "bench"
//! pool (200 models) — sequential steps bake relu (timing-neutral).

use parallel_mlps::bench_harness::{artifacts_dir, BenchArgs};
use parallel_mlps::coordinator::{render_paper_table, run_table, SweepConfig, TableKind};

fn main() {
    let args = BenchArgs::from_env();
    let mut cfg = SweepConfig::paper_grid(SweepConfig::bench_pool());
    args.apply(&mut cfg);
    let dir = args
        .args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts_dir);
    eprintln!(
        "table2: artifacts {}, grid {:?} x {:?} x {:?}, epochs {} (warmup {})",
        dir.display(),
        cfg.samples,
        cfg.features,
        cfg.batches,
        cfg.epochs,
        cfg.warmup
    );
    let cells = run_table(TableKind::Pjrt, &cfg, Some(&dir)).expect("pjrt sweep");
    let md = render_paper_table("Table 2 (PJRT device engines, 200 models)", &cfg, &cells);
    args.emit(&md);
}
