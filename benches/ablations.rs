//! Ablation benches for the design choices DESIGN.md §8 calls out:
//!
//! 1. **M3 vs masked matmul** — the paper argues (§3) that handling model
//!    independence by masking a dense block-diagonal matmul "wastes
//!    resources"; we measure both native implementations.
//! 2. **Batch-size locality** (§2.2/§5): fused pool-epoch time at fixed
//!    total work across batch sizes.
//! 3. **Group-width `W` sensitivity** — padding efficiency vs. kernel
//!    regularity in the fused layout.
//! 4. **Thread scaling** of the fused engine.
//!
//! Run: cargo bench --bench ablations [-- --quick]

use parallel_mlps::bench_harness::{measure, BenchArgs};
use parallel_mlps::coordinator::{BatchSet, SweepConfig, TrainSession};
use parallel_mlps::data;
use parallel_mlps::metrics::Table;
use parallel_mlps::nn::init::init_pool;
use parallel_mlps::nn::loss::Loss;
use parallel_mlps::nn::parallel::ParallelEngine;
use parallel_mlps::pool::PoolLayout;
use parallel_mlps::tensor::{matmul, Tensor};
use parallel_mlps::util::rng::Rng;

fn main() {
    let args = BenchArgs::from_env();
    let reps = if args.quick { 3 } else { 8 };
    let mut report = String::new();

    ablation_m3_vs_masked(&mut report, reps);
    ablation_batch_locality(&mut report, if args.quick { 2 } else { 4 });
    ablation_group_width(&mut report, if args.quick { 2 } else { 4 });
    ablation_threads(&mut report, if args.quick { 2 } else { 4 });

    args.emit(&report);
}

/// §3: M3 (contiguous segmented reduction) vs a dense block-diagonal
/// "masked" matmul that computes every (slot, hidden) pair and multiplies
/// by the mask — the strategy the paper rejects.
fn ablation_m3_vs_masked(report: &mut String, reps: usize) {
    let mut rng = Rng::new(2);
    let spec = SweepConfig::bench_pool();
    let lay = PoolLayout::build(&spec);
    let (b, o) = (32usize, 2usize);
    let h_pad = lay.h_pad();
    let m_pad = lay.m_pad();
    let mut hact = Tensor::zeros(&[b, h_pad]);
    rng.fill_normal(hact.data_mut(), 0.0, 1.0);
    let mut w2 = Tensor::zeros(&[o, h_pad]);
    rng.fill_normal(w2.data_mut(), 0.0, 1.0);
    // dense mask [h_pad, m_pad]
    let mut mask = Tensor::zeros(&[h_pad, m_pad]);
    for (pos, &s) in lay.seg_slot.iter().enumerate() {
        if s != parallel_mlps::pool::PAD_SLOT {
            mask.set2(pos, s as usize, 1.0);
        }
    }
    let spans: Vec<(usize, usize, usize)> = (0..lay.n_models())
        .map(|m| {
            let (s, e) = lay.span(m);
            (lay.slot[m], s, e)
        })
        .collect();

    let mut y_m3 = vec![0.0f32; b * m_pad * o];
    let m3 = measure("M3 segmented reduction", 2, reps, || {
        for bi in 0..b {
            let hrow = &hact.data()[bi * h_pad..(bi + 1) * h_pad];
            for &(slot, start, end) in &spans {
                for oi in 0..o {
                    let wrow = &w2.data()[oi * h_pad + start..oi * h_pad + end];
                    y_m3[(bi * m_pad + slot) * o + oi] =
                        matmul::dot(&hrow[start..end], wrow);
                }
            }
        }
        std::hint::black_box(y_m3[0]);
    });

    // masked: S[b,o,h] = H'[b,h]*W2[o,h] (materialized), then S @ mask
    let mut s_buf = vec![0.0f32; b * o * h_pad];
    let mut y_masked = vec![0.0f32; b * o * m_pad];
    let masked = measure("masked block-diagonal matmul", 2, reps, || {
        for bi in 0..b {
            for oi in 0..o {
                let hrow = &hact.data()[bi * h_pad..(bi + 1) * h_pad];
                let wrow = &w2.data()[oi * h_pad..(oi + 1) * h_pad];
                let srow = &mut s_buf[(bi * o + oi) * h_pad..(bi * o + oi + 1) * h_pad];
                for i in 0..h_pad {
                    srow[i] = hrow[i] * wrow[i];
                }
            }
        }
        matmul::matmul_nn(&s_buf, mask.data(), &mut y_masked, b * o, h_pad, m_pad, 1);
        std::hint::black_box(y_masked[0]);
    });

    // correctness cross-check while we're here
    let mut max_diff = 0.0f32;
    for bi in 0..b {
        for s in 0..m_pad {
            for oi in 0..o {
                let a = y_m3[(bi * m_pad + s) * o + oi];
                let c = y_masked[(bi * o + oi) * m_pad + s];
                max_diff = max_diff.max((a - c).abs());
            }
        }
    }
    assert!(max_diff < 1e-3, "m3 vs masked disagree: {max_diff}");

    report.push_str("### Ablation: M3 vs masked block-diagonal matmul (200-model pool)\n\n```\n");
    report.push_str(&m3.summary());
    report.push('\n');
    report.push_str(&masked.summary());
    report.push_str(&format!(
        "\nmasked/M3 time ratio: {:.2}x (paper predicts masking wastes work)\n```\n\n",
        masked.stats.mean() / m3.stats.mean()
    ));
}

/// §2.2: larger batches amortize locality — fixed total work, varying B.
fn ablation_batch_locality(report: &mut String, epochs: usize) {
    let mut rng = Rng::new(3);
    let spec = SweepConfig::bench_pool();
    let lay = PoolLayout::build(&spec);
    let (n, f, o) = (2048usize, 10usize, 2usize);
    let ds = data::random_regression(n, f, o, &mut rng);
    let mut t = Table::new(
        "Ablation: batch-size locality (fused native, fixed 2048 samples)",
        &["batch", "pool-epoch s", "samples/s"],
    );
    for &b in &[16usize, 32, 64, 128, 256] {
        let fused = init_pool(5, &lay, f, o);
        let mut engine = ParallelEngine::new(lay.clone(), fused, Loss::Mse, f, o, b, 1);
        let batches = BatchSet::new(&ds, b, true).expect("bench batches");
        let oc = TrainSession::builder()
            .epochs(epochs + 1)
            .warmup(1)
            .lr(0.01)
            .run_with_batches(&mut engine, &batches)
            .expect("native fused session")
            .outcome;
        let s = oc.avg_timed_epoch_s();
        t.row(vec![
            b.to_string(),
            format!("{s:.4}"),
            format!("{:.0}", batches.n_samples as f64 / s),
        ]);
    }
    report.push_str(&t.to_markdown());
    report.push('\n');
}

/// Group width sweep: padding vs regularity in the fused layout.
fn ablation_group_width(report: &mut String, epochs: usize) {
    let mut rng = Rng::new(4);
    let spec = SweepConfig::bench_pool();
    let (n, f, o, b) = (1024usize, 10usize, 2usize, 32usize);
    let ds = data::random_regression(n, f, o, &mut rng);
    let mut t = Table::new(
        "Ablation: group width W (fused native)",
        &["W", "G", "H_pad", "pad_eff", "pool-epoch s"],
    );
    for &w in &[32usize, 64, 128, 256] {
        let g = PoolLayout::default_group_models(&spec, w);
        let lay = PoolLayout::build_with(&spec, w, g);
        let fused = init_pool(5, &lay, f, o);
        let mut engine = ParallelEngine::new(lay.clone(), fused, Loss::Mse, f, o, b, 1);
        let batches = BatchSet::new(&ds, b, true).expect("bench batches");
        let oc = TrainSession::builder()
            .epochs(epochs + 1)
            .warmup(1)
            .lr(0.01)
            .run_with_batches(&mut engine, &batches)
            .expect("native fused session")
            .outcome;
        t.row(vec![
            w.to_string(),
            g.to_string(),
            lay.h_pad().to_string(),
            format!("{:.3}", lay.padding_efficiency()),
            format!("{:.4}", oc.avg_timed_epoch_s()),
        ]);
    }
    report.push_str(&t.to_markdown());
    report.push('\n');
}

/// Thread scaling of the fused engine (1 core here, so this documents the
/// scheduler overhead floor; on multi-core boxes it shows the speedup).
fn ablation_threads(report: &mut String, epochs: usize) {
    let mut rng = Rng::new(5);
    let spec = SweepConfig::bench_pool();
    let lay = PoolLayout::build(&spec);
    let (n, f, o, b) = (1024usize, 10usize, 2usize, 64usize);
    let ds = data::random_regression(n, f, o, &mut rng);
    let mut t = Table::new(
        "Ablation: thread scaling (fused native)",
        &["threads", "pool-epoch s"],
    );
    for &threads in &[1usize, 2, 4, 8] {
        let fused = init_pool(5, &lay, f, o);
        let mut engine = ParallelEngine::new(lay.clone(), fused, Loss::Mse, f, o, b, threads);
        let batches = BatchSet::new(&ds, b, true).expect("bench batches");
        let oc = TrainSession::builder()
            .epochs(epochs + 1)
            .warmup(1)
            .lr(0.01)
            .run_with_batches(&mut engine, &batches)
            .expect("native fused session")
            .outcome;
        t.row(vec![threads.to_string(), format!("{:.4}", oc.avg_timed_epoch_s())]);
    }
    report.push_str(&t.to_markdown());
    report.push('\n');
}
