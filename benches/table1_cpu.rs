//! Table 1 reproduction — CPU, native engines.
//!
//! Paper layout: rows = features {5,10,50,100}, cols = samples {100, 1k,
//! 10k} × batch {32,128,256}; sections Parallel / Sequential / ratio %.
//!
//! Run:  cargo bench --bench table1_cpu [-- --quick]
//!       cargo bench --bench table1_cpu -- --paper-scale --samples 100
//! Knobs: --samples/--features/--batches a,b,c  --epochs N --warmup N
//!        --threads N --out FILE --max-samples-sequential N

use parallel_mlps::bench_harness::BenchArgs;
use parallel_mlps::coordinator::{render_paper_table, run_table, SweepConfig, TableKind};
use parallel_mlps::pool::PoolSpec;

fn main() {
    let args = BenchArgs::from_env();
    let pool = if args.paper_scale {
        PoolSpec::paper_full() // h=1..100 x 10 acts x 10 reps = 10,000 models
    } else {
        SweepConfig::bench_pool() // scaled default: 200 models
    };
    let n_models = pool.n_models();
    let mut cfg = SweepConfig::paper_grid(pool);
    args.apply(&mut cfg);
    eprintln!(
        "table1: pool {} models, grid {:?} x {:?} x {:?}, epochs {} (warmup {})",
        n_models, cfg.samples, cfg.features, cfg.batches, cfg.epochs, cfg.warmup
    );
    let cells = run_table(TableKind::NativeCpu, &cfg, None).expect("native sweep");
    let title = format!("Table 1 (CPU, native engines, {n_models} models)");
    let md = render_paper_table(&title, &cfg, &cells);
    args.emit(&md);
}
